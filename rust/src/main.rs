//! specbatch CLI — the L3 coordinator entrypoint.
//!
//! Subcommands:
//!
//! * `quickstart` — load artifacts, generate a few prompts, print text;
//! * `profile`    — offline adaptive-speculation profiling (Sec. 4): grid
//!   search (b, s), print/save the LUT;
//! * `grid`       — real-execution per-token-latency grid (Fig. 1 on the
//!   tiny models);
//! * `serve`      — server+client experiment with Gamma traffic
//!   (Sec. 5.3), static or continuous batching; runs on the stub model
//!   pair when built without `--features pjrt`;
//! * `sim`        — paper-scale simulator run (choose GPU/model profiles
//!   and the scheduling mode);
//! * `inspect`    — post-hoc analysis of a telemetry JSONL dump: latency
//!   waterfalls, the batch-size × s waste surface, and the policy's
//!   predicted-vs-realized per-token audit;
//! * `warmup`     — precompile the executable matrix;
//! * `selfcheck`  — load everything and run a smoke generation.
//!
//! `specbatch <cmd> --help` prints each command's options.  Commands that
//! need real artifacts (`quickstart`, `profile`, `grid`, `warmup`,
//! `selfcheck`) require a build with `--features pjrt`.

use anyhow::{bail, Result};

use specbatch::admission::{build_controller, replicate_controllers};
use specbatch::cluster::sim::simulate_trace_cluster_admission_tel;
use specbatch::cluster::{build_router, replicate_policies};
use specbatch::config::{AdmissionSpec, PolicySpec, RouterSpec};
use specbatch::engine::prefix_cache_from_env;
use specbatch::kvcache::prefix::PrefixStats;
use specbatch::kvcache::KvLayout;
use specbatch::metrics::{LatencyRecorder, RoundEvent, SloSummary};
use specbatch::policy::{Fixed, LutAdaptive, ModelBased, NoSpec, SpeculationPolicy};
use specbatch::server::{run_experiment, Backend, SchedulingMode, ServerConfig};
use specbatch::simulator::{
    simulate_trace_admission_tel_prefix, simulate_trace_continuous_admission_tel_prefix,
    simulated_lut, AcceptanceDrift, AcceptanceProcess, CostModel, GpuProfile, ModelProfile,
    SimConfig,
};
use specbatch::telemetry::attrib::{RoundWaste, Waterfall, WasteSurface};
use specbatch::telemetry::{self, Telemetry, TelemetryMode};
use specbatch::traffic::{SharedPrefixSpec, SloSpec, Trace, TrafficPattern};
use specbatch::util::cli::{ArgSpec, Args};
use specbatch::util::json::Json;
use specbatch::{log_info, util};

#[cfg(feature = "pjrt")]
use specbatch::engine::{Engine, EngineConfig};
#[cfg(feature = "pjrt")]
use specbatch::runtime::Runtime;
#[cfg(feature = "pjrt")]
use specbatch::scheduler::profiler::{profile, ProfilerConfig};
#[cfg(feature = "pjrt")]
use specbatch::util::csv::{f as fnum, Csv};
#[cfg(feature = "pjrt")]
use specbatch::util::prng::Pcg64;

fn main() {
    util::logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: Vec<String>) -> Result<()> {
    let Some((cmd, rest)) = argv.split_first() else {
        bail!("{}", usage());
    };
    let rest = rest.to_vec();
    match cmd.as_str() {
        "quickstart" => cmd_quickstart(rest),
        "profile" => cmd_profile(rest),
        "grid" => cmd_grid(rest),
        "serve" => cmd_serve(rest),
        "sim" => cmd_sim(rest),
        "inspect" => cmd_inspect(rest),
        "warmup" => cmd_warmup(rest),
        "selfcheck" => cmd_selfcheck(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{}", usage()),
    }
}

fn usage() -> String {
    "specbatch — batched speculative decoding with adaptive speculation length\n\
     \n\
     commands:\n\
     \x20 quickstart   generate text for a few dataset prompts [pjrt]\n\
     \x20 profile      offline (batch, s) grid search -> adaptive LUT [pjrt]\n\
     \x20 grid         real-execution per-token latency grid (CSV) [pjrt]\n\
     \x20 serve        server+client Gamma-traffic experiment (static|continuous,\n\
     \x20              --workers N for the threaded stub cluster)\n\
     \x20 sim          paper-scale GPU-simulator experiment (static|continuous,\n\
     \x20              --workers N --router ... for the cluster DES)\n\
     \x20 inspect      analyze a telemetry/flight JSONL dump: latency waterfalls,\n\
     \x20              the batch-size x s waste surface, policy audit\n\
     \x20 warmup       precompile the executable matrix [pjrt]\n\
     \x20 selfcheck    smoke-test artifacts + engine [pjrt]\n\
     \n\
     run `specbatch <cmd> --help` for options"
        .to_string()
}

fn parse_mode(s: &str) -> Result<SchedulingMode> {
    match s {
        "static" => Ok(SchedulingMode::Static),
        "continuous" | "cont" => Ok(SchedulingMode::Continuous),
        other => bail!("bad mode {other:?}: expected static | continuous"),
    }
}

/// One line of SLO attainment accounting (silent when nothing carried a
/// deadline, so deadline-free runs print exactly what they used to).
fn print_slo_line(slo: &SloSummary, deferrals: usize) {
    if slo.deadlined == 0 {
        return;
    }
    println!(
        "slo: attainment {:.1}% | {} met / {} missed / {} shed of {} deadlined \
         | {} defer events",
        slo.attainment() * 100.0,
        slo.met,
        slo.missed,
        slo.shed,
        slo.deadlined,
        deferrals
    );
}

/// Resolve `--telemetry` into a live handle.  The default "auto" defers
/// to `SPECBATCH_TELEMETRY` and falls back to off, so existing command
/// lines keep the zero-overhead disabled handle.
fn parse_telemetry(args: &Args) -> Result<Telemetry> {
    let v = args.get("telemetry")?;
    let mode = if v == "auto" {
        TelemetryMode::default_mode()
    } else {
        TelemetryMode::parse(v)?
    };
    Ok(Telemetry::new(mode))
}

/// Attach the always-on flight recorder when `--flight` is set.  This
/// deliberately works with `--telemetry off`: the ring records (and the
/// SIGUSR1 dump handler installs) regardless of the event sink.
fn attach_flight(args: &Args, tel: Telemetry) -> Result<Telemetry> {
    if !args.has_flag("flight") {
        return Ok(tel);
    }
    let fr = telemetry::flight::FlightRecorder::new(
        args.get_usize("flight-slots")?,
        args.get("flight-out")?,
    );
    telemetry::flight::install_sigusr1();
    Ok(tel.with_flight(fr))
}

/// Final flight dump: whatever the ring holds at exit is written, so a
/// run that never hit an anomaly trigger still leaves its last rounds
/// on disk for `inspect`.
fn finish_flight(tel: &Telemetry) -> Result<()> {
    if let Some(fr) = tel.flight() {
        for p in fr.dump_now()? {
            println!("flight -> {}", p.display());
        }
    }
    Ok(())
}

/// The `--flight*` knobs shared by `serve` and `sim`.
fn flight_opts(spec: ArgSpec, default_prefix: &'static str) -> ArgSpec {
    spec.flag(
        "flight",
        "always-on flight recorder (records even at --telemetry off; SIGUSR1 dumps)",
    )
    .opt("flight-slots", "256", "flight ring capacity (rounded up to a power of two)")
    .opt("flight-out", default_prefix, "flight dump prefix (<prefix>.<seq>.{trace.json,jsonl})")
}

/// The `sim` knobs folded into the bench report's config fingerprint
/// (shared by the single-worker and cluster branches).
const SIM_CONFIG_KEYS: &[&str] = &[
    "gpu", "llm", "ssm", "policy", "mode", "workers", "router", "requests", "interval", "cv",
    "prompt-len", "kv-layout", "admission", "slo-p50", "slo-scale", "seed", "drift-at",
    "drift-c", "drift-gamma", "prefix-cache", "tenants", "templates",
];

/// Snapshot the experiment knobs into a stable JSON object for the bench
/// report's config fingerprint (BTreeMap keys make it order-independent).
fn cli_config_json(cmd: &str, args: &Args, keys: &[&str]) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![("cmd", Json::Str(cmd.into()))];
    for &k in keys {
        if let Ok(v) = args.get(k) {
            pairs.push((k, Json::Str(v.into())));
        }
    }
    pairs.push(("fig6", Json::Bool(args.has_flag("fig6"))));
    pairs.push(("mixed-domain", Json::Bool(args.has_flag("mixed-domain"))));
    pairs.push(("shared-prefix", Json::Bool(args.has_flag("shared-prefix"))));
    Json::obj(pairs)
}

/// The prefix-sharing knobs shared by `serve` and `sim`.
fn prefix_opts(spec: ArgSpec) -> ArgSpec {
    spec.opt(
        "prefix-cache",
        "auto",
        "auto | on | off — share KV blocks across identical prompt prefixes \
         (auto = $SPECBATCH_PREFIX_CACHE, else off; needs --kv-layout paged)",
    )
    .flag(
        "shared-prefix",
        "multi-tenant traffic: every prompt becomes a Zipf-weighted \
         (tenant, template) system prefix plus a tiny unique user tail",
    )
    .opt("tenants", "4", "shared-prefix tenant count")
    .opt("templates", "4", "shared-prefix templates per tenant")
}

/// Resolve `--prefix-cache auto|on|off`; `auto` defers to the
/// environment.  Sharing needs a block table, so a dense layout forces
/// the cache off — explicitly asking for both is an error.
fn resolve_prefix_cache(args: &Args, layout: KvLayout) -> Result<bool> {
    let raw = args.get("prefix-cache")?;
    let on = match raw {
        "auto" => prefix_cache_from_env(),
        "on" => true,
        "off" => false,
        other => bail!("--prefix-cache must be auto|on|off, got {other:?}"),
    };
    if on && layout == KvLayout::Dense {
        if raw == "on" {
            bail!("--prefix-cache on needs --kv-layout paged (dense has no block table to share)");
        }
        return Ok(false); // env said on, layout can't: silently degrade
    }
    Ok(on)
}

/// `--shared-prefix` layers the multi-tenant template structure onto an
/// already generated trace (arrival times and deadlines are untouched).
fn apply_shared_prefix(args: &Args, trace: Trace) -> Result<Trace> {
    if !args.has_flag("shared-prefix") {
        return Ok(trace);
    }
    let spec = SharedPrefixSpec {
        tenants: args.get_usize("tenants")?,
        templates: args.get_usize("templates")?,
        ..SharedPrefixSpec::default()
    };
    Ok(trace.with_shared_prefix(&spec, args.get_u64("seed")?))
}

fn print_prefix_stats(stats: &Option<PrefixStats>) {
    if let Some(p) = stats {
        println!(
            "prefix cache: {:.1}% hit rate over {} lookups | {} prefill tokens saved \
             | {} cow copies | {} evictions | {} blocks cached at shutdown",
            p.hit_rate() * 100.0,
            p.lookups,
            p.prefill_tokens_saved,
            p.cow_copies,
            p.evictions,
            p.cached_blocks
        );
    }
}

/// Post-run telemetry output: write the enabled exporters under the
/// `--telemetry-out` prefix and, when `--bench-out` names a figure, the
/// `BENCH_<name>.json` report.  No-op (and prints nothing) when the
/// handle is disabled, so default runs are byte-identical.
fn finish_telemetry(
    tel: &Telemetry,
    prefix: &str,
    bench_name: &str,
    recorder: &LatencyRecorder,
    rounds: &[RoundEvent],
    config: Json,
) -> Result<()> {
    if !tel.enabled() {
        return Ok(());
    }
    for path in telemetry::export::write_all(tel, prefix)? {
        println!("telemetry -> {}", path.display());
    }
    if !bench_name.is_empty() {
        let report = telemetry::bench::bench_report(bench_name, recorder, rounds, config);
        let path = telemetry::bench::write_bench(bench_name, &report)?;
        println!("bench -> {}", path.display());
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_unavailable(cmd: &str, _argv: Vec<String>) -> Result<()> {
    bail!(
        "`{cmd}` drives the real PJRT runtime — uncomment the `xla` dependency \
         in rust/Cargo.toml, rebuild with `--features pjrt`, and run \
         `make artifacts` first; the default build serves the deterministic \
         stub pair via `serve`/`sim` (see DESIGN.md §Feature flags)"
    )
}

// ---------------------------------------------------------------- pjrt-only

#[cfg(feature = "pjrt")]
fn common_spec(name: &'static str, about: &'static str) -> ArgSpec {
    ArgSpec::new(name, about).opt("artifacts", "artifacts", "artifacts directory")
}

#[cfg(feature = "pjrt")]
fn load_runtime(args: &Args) -> Result<Runtime> {
    Runtime::load(std::path::PathBuf::from(args.get("artifacts")?))
}

#[cfg(feature = "pjrt")]
fn parse_policy(
    args: &Args,
    rt: &Runtime,
    engine: &mut Engine<'_>,
) -> Result<Box<dyn SpeculationPolicy>> {
    let profiled_lut = |engine: &mut Engine<'_>| -> Result<specbatch::scheduler::Lut> {
        let dataset = rt.dataset()?;
        let mut rng = Pcg64::new(0xADA);
        let prompts = dataset.sample_profile(&mut rng, 24);
        let mut pcfg = ProfilerConfig::from_manifest(&rt.manifest);
        pcfg.tokens_per_run = 16;
        pcfg.repeats = 1;
        Ok(profile(engine, &prompts, &pcfg)?.lut)
    };
    Ok(match PolicySpec::parse(args.get("policy")?)? {
        PolicySpec::None => Box::new(NoSpec),
        PolicySpec::Fixed(s) => Box::new(Fixed(s)),
        PolicySpec::Adaptive => Box::new(LutAdaptive(profiled_lut(engine)?)),
        PolicySpec::ModelBased => Box::new(ModelBased::new(profiled_lut(engine)?)),
    })
}

#[cfg(feature = "pjrt")]
fn cmd_quickstart(argv: Vec<String>) -> Result<()> {
    let spec = common_spec("quickstart", "generate text for a few dataset prompts")
        .opt("prompts", "3", "number of prompts")
        .opt("tokens", "32", "new tokens per prompt")
        .opt("policy", "fixed:3", "none | fixed:<s> | adaptive | model-based");
    let args = spec.parse(&argv)?;
    let rt = load_runtime(&args)?;
    let dataset = rt.dataset()?;
    let mut engine = Engine::new(&rt, EngineConfig::default())?;
    let mut policy = parse_policy(&args, &rt, &mut engine)?;

    let mut rng = Pcg64::new(7);
    let n = args.get_usize("prompts")?;
    let prompts = dataset.sample_eval(&mut rng, n);
    let ids: Vec<Vec<i32>> = prompts.iter().map(|p| p.ids.clone()).collect();
    let out = engine.generate_batch(&ids, args.get_usize("tokens")?, policy.as_mut())?;

    for (p, toks) in prompts.iter().zip(&out.tokens) {
        println!("prompt: {}", p.text);
        println!("  -> {}", dataset.detokenize(toks));
    }
    let st = &out.stats;
    println!(
        "\npolicy {} | {} rounds | {:.2} drafts accepted/round | {:.2} ms/token",
        policy.label(),
        st.rounds,
        st.mean_accepted(),
        st.per_token_latency() * 1e3,
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_quickstart(argv: Vec<String>) -> Result<()> {
    pjrt_unavailable("quickstart", argv)
}

#[cfg(feature = "pjrt")]
fn cmd_profile(argv: Vec<String>) -> Result<()> {
    let spec = common_spec("profile", "grid-search (batch, s) and build the adaptive LUT")
        .opt("tokens", "24", "tokens per measurement run")
        .opt("repeats", "2", "measurement repeats per grid point")
        .opt("prompts", "32", "profile prompts sampled")
        .opt("out", "results/profile", "output prefix (CSV + LUT json)");
    let args = spec.parse(&argv)?;
    let rt = load_runtime(&args)?;
    let dataset = rt.dataset()?;
    let mut engine = Engine::new(&rt, EngineConfig::default())?;
    let mut rng = Pcg64::new(0xADA);
    let prompts = dataset.sample_profile(&mut rng, args.get_usize("prompts")?);
    let mut pcfg = ProfilerConfig::from_manifest(&rt.manifest);
    pcfg.tokens_per_run = args.get_usize("tokens")?;
    pcfg.repeats = args.get_usize("repeats")?;
    let result = profile(&mut engine, &prompts, &pcfg)?;

    let prefix = args.get("out")?;
    result.to_csv().write_file(format!("{prefix}_grid.csv"))?;
    result.lut.to_json().write_file(format!("{prefix}_lut.json"))?;
    println!("LUT: {}", result.lut.to_json().compact());
    println!("grid -> {prefix}_grid.csv, lut -> {prefix}_lut.json");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_profile(argv: Vec<String>) -> Result<()> {
    pjrt_unavailable("profile", argv)
}

#[cfg(feature = "pjrt")]
fn cmd_grid(argv: Vec<String>) -> Result<()> {
    let spec = common_spec("grid", "real-execution per-token latency grid (tiny models)")
        .opt("buckets", "1,2,4,8", "batch buckets to measure")
        .opt("slens", "0,1,2,3,4,5,6", "speculation lengths")
        .opt("tokens", "24", "tokens per measurement")
        .opt("out", "results/grid_real.csv", "output CSV");
    let args = spec.parse(&argv)?;
    let rt = load_runtime(&args)?;
    let dataset = rt.dataset()?;
    let mut engine = Engine::new(&rt, EngineConfig::default())?;
    let mut rng = Pcg64::new(3);
    let tokens = args.get_usize("tokens")?;

    let mut csv = Csv::new(&["batch", "s", "per_token_latency_ms", "mean_accepted"]);
    for b in args.get_usize_list("buckets")? {
        for s in args.get_usize_list("slens")? {
            if s > 0 && rt.manifest.max_spec_len(b) < s {
                continue;
            }
            let prompts: Vec<Vec<i32>> = dataset
                .sample_eval(&mut rng, b)
                .into_iter()
                .map(|p| p.ids)
                .collect();
            let mut policy: Box<dyn SpeculationPolicy> = if s == 0 {
                Box::new(NoSpec)
            } else {
                Box::new(Fixed(s))
            };
            let out = engine.generate_batch(&prompts, tokens, policy.as_mut())?;
            let lat = out.stats.per_token_latency() * 1e3;
            println!(
                "b={b} s={s}: {lat:.3} ms/token (accepted {:.2}/round)",
                out.stats.mean_accepted()
            );
            csv.row(&[
                b.to_string(),
                s.to_string(),
                fnum(lat),
                fnum(out.stats.mean_accepted()),
            ]);
        }
    }
    csv.write_file(args.get("out")?)?;
    println!("-> {}", args.get("out")?);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_grid(argv: Vec<String>) -> Result<()> {
    pjrt_unavailable("grid", argv)
}

#[cfg(feature = "pjrt")]
fn cmd_warmup(argv: Vec<String>) -> Result<()> {
    let spec = common_spec("warmup", "precompile the executable matrix")
        .opt("max-batch", "16", "largest bucket to compile")
        .opt("max-s", "8", "largest speculation length to compile");
    let args = spec.parse(&argv)?;
    let rt = load_runtime(&args)?;
    let n = rt.warmup(args.get_usize("max-batch")?, args.get_usize("max-s")?)?;
    let (compiled, secs) = rt.compile_stats();
    println!("{n} executables ready ({compiled} compiled in {secs:.1}s)");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_warmup(argv: Vec<String>) -> Result<()> {
    pjrt_unavailable("warmup", argv)
}

#[cfg(feature = "pjrt")]
fn cmd_selfcheck(argv: Vec<String>) -> Result<()> {
    let spec = common_spec("selfcheck", "smoke-test artifacts + engine");
    let args = spec.parse(&argv)?;
    let rt = load_runtime(&args)?;
    println!(
        "manifest: fingerprint {} profile {} ({} executables)",
        rt.manifest.fingerprint,
        rt.manifest.profile,
        rt.manifest.executables.len()
    );
    println!(
        "models: llm {} params, ssm {} params, agreement {:.3}",
        rt.manifest.models["llm"].n_params,
        rt.manifest.models["ssm"].n_params,
        rt.manifest.agreement_rate
    );
    let dataset = rt.dataset()?;
    println!(
        "dataset: {} profile / {} eval prompts, vocab {}",
        dataset.profile.len(),
        dataset.eval.len(),
        dataset.vocab.len()
    );
    let mut engine = Engine::new(
        &rt,
        EngineConfig {
            stop_at_eos: false,
            ..EngineConfig::default()
        },
    )?;
    let goldens = Json::parse_file(rt.manifest.dir.join(&rt.manifest.goldens_file))?;
    let case = &goldens.get("cases")?.as_arr()?[0];
    let prompt: Vec<i32> = case
        .get("prompt")?
        .as_arr()?
        .iter()
        .map(|v| Ok(v.as_i64()? as i32))
        .collect::<Result<_>>()?;
    let expect: Vec<i32> = case
        .get("greedy")?
        .as_arr()?
        .iter()
        .map(|v| Ok(v.as_i64()? as i32))
        .collect::<Result<_>>()?;
    let out = engine.generate_batch(&[prompt], expect.len(), &mut Fixed(3))?;
    if out.tokens[0] != expect {
        bail!("selfcheck FAILED: engine output diverges from golden");
    }
    println!("selfcheck OK: speculative output matches the Python golden");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_selfcheck(argv: Vec<String>) -> Result<()> {
    pjrt_unavailable("selfcheck", argv)
}

// ------------------------------------------------------------- both builds

/// Backend + prompt pool for `serve`: real artifacts under `pjrt`, the
/// stub model pair (with a synthetic prompt pool) otherwise.
#[cfg(feature = "pjrt")]
fn serve_backend(args: &Args) -> Result<(Backend, Vec<specbatch::dataset::Prompt>)> {
    let artifacts = std::path::PathBuf::from(args.get("artifacts")?);
    let dataset = specbatch::dataset::Dataset::load(artifacts.join("dataset.json"))?;
    Ok((Backend::Artifacts(artifacts), dataset.eval.clone()))
}

#[cfg(not(feature = "pjrt"))]
fn serve_backend(args: &Args) -> Result<(Backend, Vec<specbatch::dataset::Prompt>)> {
    let _ = args;
    let spec = specbatch::testkit::stub::StubSpec::default();
    let pool: Vec<specbatch::dataset::Prompt> = (4..=12usize)
        .map(|n| specbatch::dataset::Prompt {
            ids: (0..n).map(|k| 4 + ((k * 7 + n) % 60) as i32).collect(),
            text: format!("stub prompt of {n} tokens"),
        })
        .collect();
    Ok((Backend::Stub(spec), pool))
}

fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let spec = ArgSpec::new(
        "serve",
        "server+client Gamma-traffic experiment (Sec. 5.3); stub backend without --features pjrt",
    )
    .opt("artifacts", "artifacts", "artifacts directory (pjrt builds)")
    .opt("policy", "adaptive", "none | fixed:<s> | adaptive | model-based")
    .opt("mode", "static", "static | continuous")
    .opt("workers", "1", "worker shards (> 1 = threaded cluster, continuous mode)")
    .opt("router", "cost-aware", "round-robin | jsq | power-of-two | cost-aware | deadline")
    .opt("requests", "64", "number of requests")
    .opt("interval", "0.5", "mean inter-arrival seconds")
    .opt("cv", "1.0", "coefficient of variation")
    .opt("tokens", "32", "new tokens per request")
    .opt("max-batch", "8", "dynamic batching cap (per shard)")
    .opt(
        "kv-layout",
        "dense",
        "dense | paged (paged = O(1) epoch reshape via block tables, stub backend)",
    )
    .opt("admission", "fifo", "fifo | edf | slo (queue ordering / defer / shed)")
    .opt("slo-p50", "0", "median latency budget in seconds (0 = no deadlines)")
    .opt("slo-scale", "1", "log-uniform budget spread factor (>= 1)")
    .opt("seed", "1", "trace seed")
    .flag("fig6", "use the alternating intense/sparse pattern")
    .opt("out", "results/serve.csv", "per-request CSV")
    .opt("rounds-out", "results/serve_rounds.csv", "per-round timeline CSV")
    .opt(
        "telemetry",
        "auto",
        "off | summary | trace (auto = $SPECBATCH_TELEMETRY, else off)",
    )
    .opt(
        "telemetry-out",
        "results/serve_telemetry",
        "exporter prefix (.prom / .trace.json / .events.jsonl)",
    )
    .opt("bench-out", "", "emit BENCH_<name>.json via telemetry::bench (empty = skip)");
    let spec = prefix_opts(spec);
    let spec = flight_opts(spec, "results/serve_flight");
    let args = spec.parse(&argv)?;

    let mode = parse_mode(args.get("mode")?)?;
    let (backend, pool) = serve_backend(&args)?;
    let pattern = if args.has_flag("fig6") {
        TrafficPattern::fig6()
    } else {
        TrafficPattern::Stationary {
            interval: args.get_f64("interval")?,
            cv: args.get_f64("cv")?,
        }
    };
    let mut trace = Trace::generate(
        &pattern,
        &pool,
        args.get_usize("requests")?,
        args.get_u64("seed")?,
    );
    trace = apply_shared_prefix(&args, trace)?;
    let slo_p50 = args.get_f64("slo-p50")?;
    if slo_p50 > 0.0 {
        let slo = SloSpec::new(slo_p50, args.get_f64("slo-scale")?);
        trace = trace.with_deadlines(&slo, args.get_u64("seed")?);
    }
    log_info!(
        "trace: {} requests over {:.1}s ({})",
        trace.len(),
        trace.span(),
        pattern.label()
    );

    let workers = args.get_usize("workers")?;
    let router = RouterSpec::parse(args.get("router")?)?;
    let tel = attach_flight(&args, parse_telemetry(&args)?)?;
    let kv_layout = KvLayout::parse(args.get("kv-layout")?)?;
    let cfg = ServerConfig {
        max_batch: args.get_usize("max-batch")?,
        max_new_tokens: args.get_usize("tokens")?,
        mode,
        workers,
        router,
        kv_layout,
        prefix_cache: resolve_prefix_cache(&args, kv_layout)?,
        admission: AdmissionSpec::parse(args.get("admission")?)?,
        telemetry: tel.clone(),
        ..ServerConfig::default()
    };
    let policy = PolicySpec::parse(args.get("policy")?)?;
    let out = run_experiment(backend, cfg, policy, None, &trace)?;

    if let Some(lut) = &out.lut {
        println!("offline LUT: {}", lut.to_json().compact());
    }
    if let Some(snapshot) = &out.policy_snapshot {
        println!("fitted model: {}", snapshot.compact());
    }
    if let Some(kv) = &out.kv_blocks {
        println!(
            "kv blocks: peak {} / {} ({} tokens each, internal frag {:.1}%){}",
            kv.peak_in_use,
            kv.capacity,
            kv.block_size,
            kv.mean_internal_frag * 100.0,
            if kv.is_leak_free() { "" } else { " — LEAKED" }
        );
    }
    print_prefix_stats(&out.prefix);
    let s = out.recorder.summary();
    let (p50, p90, p99) = out.recorder.percentiles();
    println!(
        "{mode:?} | {} requests | latency mean {:.3}s p50 {:.3}s p90 {:.3}s p99 {:.3}s \
         | {:.1} tok/s",
        s.n,
        s.mean,
        p50,
        p90,
        p99,
        out.recorder.throughput_tokens_per_s()
    );
    print_slo_line(&out.recorder.slo_attainment(), out.deferrals);
    if !out.shards.is_empty() {
        println!("router {} over {} shards:", router.label(), out.shards.len());
        for b in &out.shards {
            let slo = if b.slo.deadlined > 0 {
                format!(
                    " | attainment {:.1}% ({} shed)",
                    b.slo.attainment() * 100.0,
                    b.slo.shed
                )
            } else {
                String::new()
            };
            println!(
                "  shard {} | {:>4} requests | mean latency {:.3}s | mean live {:.1} \
                 | mean s {:.2} | {} rounds{}",
                b.shard,
                b.requests,
                b.mean_latency,
                b.mean_live(),
                b.mean_s(),
                b.rounds.len(),
                slo
            );
        }
    }
    out.recorder.to_csv().write_file(args.get("out")?)?;
    println!("-> {}", args.get("out")?);
    if !out.timeline.is_empty() {
        specbatch::metrics::rounds_to_csv(&out.timeline).write_file(args.get("rounds-out")?)?;
        println!("rounds -> {}", args.get("rounds-out")?);
    }
    finish_telemetry(
        &tel,
        args.get("telemetry-out")?,
        args.get("bench-out")?,
        &out.recorder,
        &out.timeline,
        cli_config_json(
            "serve",
            &args,
            &[
                "policy", "mode", "workers", "router", "requests", "interval", "cv", "tokens",
                "max-batch", "kv-layout", "admission", "slo-p50", "slo-scale", "seed",
                "prefix-cache", "tenants", "templates",
            ],
        ),
    )?;
    finish_flight(&tel)?;
    Ok(())
}

fn cmd_sim(argv: Vec<String>) -> Result<()> {
    let spec = ArgSpec::new("sim", "paper-scale GPU-simulator experiment")
        .opt("gpu", "rtx3090", "rtx3090 | rtx4090 | a100")
        .opt("llm", "opt-6.7b", "opt-1.3b | opt-6.7b | llama-7b")
        .opt("ssm", "opt-125m", "draft model profile")
        .opt("policy", "adaptive", "none | fixed:<s> | adaptive | model-based")
        .opt("mode", "static", "static | continuous")
        .opt("workers", "1", "worker shards (> 1 = cluster DES, continuous rounds)")
        .opt("router", "cost-aware", "round-robin | jsq | power-of-two | cost-aware | deadline")
        .opt("requests", "1000", "number of requests")
        .opt("interval", "0.3", "mean inter-arrival seconds")
        .opt("cv", "1.0", "coefficient of variation")
        .opt("prompt-len", "16", "prompt length")
        .opt(
            "kv-layout",
            "paged",
            "paged | dense (dense charges the chunked reshape re-ingest the \
             engine pays without a block manager)",
        )
        .opt("admission", "fifo", "fifo | edf | slo (queue ordering / defer / shed)")
        .opt("slo-p50", "0", "median latency budget in seconds (0 = no deadlines)")
        .opt("slo-scale", "1", "log-uniform budget spread factor (>= 1)")
        .opt("seed", "1", "trace seed")
        .opt("drift-at", "0", "acceptance drift time in virtual seconds (0 = off)")
        .opt("drift-c", "0.55", "post-drift acceptance c")
        .opt("drift-gamma", "0.2", "post-drift acceptance gamma")
        .flag("fig6", "use the alternating intense/sparse pattern")
        .flag(
            "mixed-domain",
            "tag requests with two alternating workload classes and give each its \
             own acceptance regime (geometric q=0.75 vs q=0.05) — the ragged \
             per-row speculation showcase",
        )
        .opt("out", "results/sim.csv", "per-request CSV")
        .opt("rounds-out", "results/sim_rounds.csv", "per-round timeline CSV")
        .opt(
            "telemetry",
            "auto",
            "off | summary | trace (auto = $SPECBATCH_TELEMETRY, else off)",
        )
        .opt(
            "telemetry-out",
            "results/sim_telemetry",
            "exporter prefix (.prom / .trace.json / .events.jsonl)",
        )
        .opt("bench-out", "", "emit BENCH_<name>.json via telemetry::bench (empty = skip)");
    let spec = prefix_opts(spec);
    let spec = flight_opts(spec, "results/sim_flight");
    let args = spec.parse(&argv)?;
    let tel = attach_flight(&args, parse_telemetry(&args)?)?;
    let mode = parse_mode(args.get("mode")?)?;
    let gpu_name = args.get("gpu")?.to_string();
    let gpu = GpuProfile::by_name(&gpu_name)
        .ok_or_else(|| anyhow::anyhow!("unknown gpu {gpu_name:?}"))?;
    let llm_name = args.get("llm")?.to_string();
    let llm = ModelProfile::by_name(&llm_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {llm_name:?}"))?;
    let ssm_name = args.get("ssm")?.to_string();
    let ssm = ModelProfile::by_name(&ssm_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {ssm_name:?}"))?;
    let drift_at = args.get_f64("drift-at")?;
    let drift = if drift_at > 0.0 {
        Some(AcceptanceDrift {
            at: drift_at,
            after: AcceptanceProcess::PowerLaw {
                c: args.get_f64("drift-c")?,
                gamma: args.get_f64("drift-gamma")?,
            },
        })
    } else {
        None
    };
    let kv_layout = KvLayout::parse(args.get("kv-layout")?)?;
    let mut cfg = SimConfig {
        llm: CostModel::new(llm, gpu),
        ssm: CostModel::new(ssm, gpu),
        acceptance: AcceptanceProcess::paper(),
        class_acceptance: Default::default(),
        drift,
        max_batch: 16,
        max_new_tokens: 128,
        host_overhead: 0.2e-3,
        kv_layout,
        kv_block: specbatch::kvcache::DEFAULT_BLOCK_SIZE,
        prefix_cache: resolve_prefix_cache(&args, kv_layout)?,
        seed: args.get_u64("seed")?,
    };
    if args.has_flag("mixed-domain") {
        // two acceptance regimes in one batch: class 0 drafts land often,
        // class 1 almost never — the scenario where a ragged per-row
        // policy beats every uniform speculation length (q matches the
        // gated payoff test in tests/ragged_policy.rs; q -> 1 makes huge
        // per-class s genuinely optimal and is a different story)
        cfg.class_acceptance
            .insert(0, AcceptanceProcess::Geometric { q: 0.75 });
        cfg.class_acceptance
            .insert(1, AcceptanceProcess::Geometric { q: 0.05 });
    }
    let policy_spec = PolicySpec::parse(args.get("policy")?)?;
    let pattern = if args.has_flag("fig6") {
        TrafficPattern::fig6()
    } else {
        TrafficPattern::Stationary {
            interval: args.get_f64("interval")?,
            cv: args.get_f64("cv")?,
        }
    };
    let plen = args.get_usize("prompt-len")?;
    let pool = vec![specbatch::dataset::Prompt {
        ids: vec![1; plen],
        text: String::new(),
    }];
    let mut trace = Trace::generate(
        &pattern,
        &pool,
        args.get_usize("requests")?,
        args.get_u64("seed")?,
    );
    if args.has_flag("mixed-domain") {
        trace = trace.with_classes_alternating(2);
    }
    trace = apply_shared_prefix(&args, trace)?;
    let slo_p50 = args.get_f64("slo-p50")?;
    if slo_p50 > 0.0 {
        let slo = SloSpec::new(slo_p50, args.get_f64("slo-scale")?);
        trace = trace.with_deadlines(&slo, args.get_u64("seed")?);
    }
    let admission = AdmissionSpec::parse(args.get("admission")?)?;

    let workers = args.get_usize("workers")?;
    if workers > 1 {
        // cluster DES: N shards with per-shard virtual clocks and policy
        // instances, arrivals routed by the chosen strategy
        if mode == SchedulingMode::Static {
            log_info!("sim: cluster shards always run continuous rounds (--mode ignored)");
        }
        let router_spec = RouterSpec::parse(args.get("router")?)?;
        let lut = match policy_spec {
            PolicySpec::Adaptive | PolicySpec::ModelBased => {
                let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
                println!("offline LUT: {}", lut.to_json().compact());
                Some(lut)
            }
            _ => None,
        };
        let mut policies = replicate_policies(&policy_spec, lut.as_ref(), workers)?;
        let mut ctrls = replicate_controllers(admission, workers);
        let mut router = build_router(router_spec, args.get_u64("seed")?);
        let report = simulate_trace_cluster_admission_tel(
            &cfg,
            &mut policies,
            &mut ctrls,
            router.as_mut(),
            &trace,
            &tel,
        );
        let s = report.recorder.summary();
        let (p50, p90, p99) = report.recorder.percentiles();
        println!(
            "{} on {} | {} x{workers} | router {} | {} requests | latency mean {:.3}s \
             p50 {:.3}s p90 {:.3}s p99 {:.3}s | {:.2} ms/token",
            llm.name,
            gpu.name,
            policy_spec.label(),
            report.router,
            s.n,
            s.mean,
            p50,
            p90,
            p99,
            report.recorder.mean_per_token_latency() * 1e3
        );
        let defer_events = report
            .recorder
            .records()
            .iter()
            .map(|r| r.deferred_rounds)
            .sum();
        print_slo_line(&report.recorder.slo_attainment(), defer_events);
        print_prefix_stats(&report.prefix);
        let counts = report.shard_requests();
        let attain = report.shard_attainment();
        for (k, rounds) in report.shard_rounds.iter().enumerate() {
            let mean_live = rounds.iter().map(|e| e.live as f64).sum::<f64>()
                / rounds.len().max(1) as f64;
            let mean_s = rounds.iter().map(|e| e.s as f64).sum::<f64>()
                / rounds.len().max(1) as f64;
            let slo = if attain[k].deadlined > 0 {
                format!(
                    " | attainment {:.1}% ({} shed)",
                    attain[k].attainment() * 100.0,
                    attain[k].shed
                )
            } else {
                String::new()
            };
            println!(
                "  shard {k} | {:>5} requests | {:>6} rounds | mean live {mean_live:.1} \
                 | mean s {mean_s:.2}{slo}",
                counts[k],
                rounds.len()
            );
        }
        report.recorder.to_csv().write_file(args.get("out")?)?;
        println!("-> {} (per-request, shard column)", args.get("out")?);
        // per-shard round timelines: one file per shard, derived from
        // the --rounds-out path
        let rounds_out = args.get("rounds-out")?;
        let stem = rounds_out.strip_suffix(".csv").unwrap_or(rounds_out);
        for (k, rounds) in report.shard_rounds.iter().enumerate() {
            let path = format!("{stem}.shard{k}.csv");
            specbatch::metrics::rounds_to_csv(rounds).write_file(&path)?;
            println!("rounds (shard {k}) -> {path}");
        }
        // bench reports want one merged timeline; shard clocks share the
        // experiment origin, so a sort by round boundary suffices
        let mut merged: Vec<RoundEvent> =
            report.shard_rounds.iter().flatten().copied().collect();
        merged.sort_by(|a, b| a.t.total_cmp(&b.t));
        finish_telemetry(
            &tel,
            args.get("telemetry-out")?,
            args.get("bench-out")?,
            &report.recorder,
            &merged,
            cli_config_json("sim", &args, SIM_CONFIG_KEYS),
        )?;
        finish_flight(&tel)?;
        return Ok(());
    }

    let mut policy: Box<dyn SpeculationPolicy> = match policy_spec {
        PolicySpec::None => Box::new(NoSpec),
        PolicySpec::Fixed(s) => Box::new(Fixed(s)),
        // both LUT-seeded policies share the simulator-derived table
        spec @ (PolicySpec::Adaptive | PolicySpec::ModelBased) => {
            let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
            println!("offline LUT: {}", lut.to_json().compact());
            if spec == PolicySpec::Adaptive {
                Box::new(LutAdaptive(lut))
            } else {
                Box::new(ModelBased::new(lut))
            }
        }
    };
    let mut ctrl = build_controller(admission);
    let (rec, rounds, prefix_stats) = match mode {
        SchedulingMode::Static => {
            let (rec, ps) = simulate_trace_admission_tel_prefix(
                &cfg,
                policy.as_mut(),
                ctrl.as_mut(),
                &trace,
                &tel,
            );
            (rec, Vec::new(), ps)
        }
        SchedulingMode::Continuous => simulate_trace_continuous_admission_tel_prefix(
            &cfg,
            policy.as_mut(),
            ctrl.as_mut(),
            &trace,
            &tel,
        ),
    };
    if let Some(snapshot) = policy.snapshot() {
        println!("fitted model: {}", snapshot.compact());
    }
    let s = rec.summary();
    let (p50, p90, p99) = rec.percentiles();
    println!(
        "{} on {} | {} | {} | {mode:?} | {} requests | latency mean {:.3}s p50 {:.3}s \
         p90 {:.3}s p99 {:.3}s",
        llm.name,
        gpu.name,
        policy.label(),
        ctrl.label(),
        s.n,
        s.mean,
        p50,
        p90,
        p99
    );
    print_slo_line(
        &rec.slo_attainment(),
        rec.records().iter().map(|r| r.deferred_rounds).sum(),
    );
    print_prefix_stats(&prefix_stats);
    rec.to_csv().write_file(args.get("out")?)?;
    println!("-> {}", args.get("out")?);
    if !rounds.is_empty() {
        specbatch::metrics::rounds_to_csv(&rounds).write_file(args.get("rounds-out")?)?;
        println!("rounds -> {}", args.get("rounds-out")?);
    }
    finish_telemetry(
        &tel,
        args.get("telemetry-out")?,
        args.get("bench-out")?,
        &rec,
        &rounds,
        cli_config_json("sim", &args, SIM_CONFIG_KEYS),
    )?;
    finish_flight(&tel)?;
    Ok(())
}

/// `inspect` — parse a telemetry events JSONL (the `--telemetry-out`
/// export or a flight-recorder dump: both carry the same per-line event
/// schema) and print the three causal-attribution reports:
///
/// 1. the mean per-request latency **waterfall** (every component plus
///    the sealed remainder — the components tile latency exactly);
/// 2. the batch-size × s **waste surface** (rejected-draft and
///    bucket-padding slots as fractions of executed slots, plus SSM
///    catch-up seconds);
/// 3. the **policy audit**: the last fitted-model snapshot's predicted
///    vs realized per-token cost per bucket and the committed s ladder.
fn cmd_inspect(argv: Vec<String>) -> Result<()> {
    let spec = ArgSpec::new(
        "inspect",
        "analyze a telemetry/flight JSONL dump (waterfalls, waste surface, policy audit)",
    )
    .opt(
        "events",
        "results/serve_telemetry.events.jsonl",
        "events JSONL: a --telemetry-out export or a flight dump",
    );
    let args = spec.parse(&argv)?;
    let path = args.get("events")?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("inspect: cannot read {path}: {e}"))?;

    let num = |j: &Json, k: &str| -> Option<f64> { j.get(k).ok()?.as_f64().ok() };
    let idx = |j: &Json, k: &str| -> Option<usize> { j.get(k).ok()?.as_usize().ok() };

    let mut finished: Vec<Waterfall> = Vec::new();
    let mut shed = 0usize;
    let mut surface = WasteSurface::default();
    // the catch-up phase span of a round follows its round event in the
    // stream, so the last round cell owns subsequent catch-up seconds
    let mut last_cell: Option<(usize, usize)> = None;
    let mut catch_up_total = 0.0f64;
    let mut triggers: std::collections::BTreeMap<String, usize> = Default::default();
    let mut snapshot: Option<Json> = None;
    let (mut events, mut skipped) = (0usize, 0usize);

    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let Ok(j) = Json::parse(line) else {
            skipped += 1;
            continue;
        };
        let Some(ev) = j.get("ev").ok().and_then(|v| v.as_str().ok().map(String::from))
        else {
            skipped += 1;
            continue;
        };
        events += 1;
        match ev.as_str() {
            "round" => {
                let (Some(width), Some(live), Some(s), Some(dur)) = (
                    idx(&j, "width"),
                    idx(&j, "live"),
                    idx(&j, "s"),
                    num(&j, "dur"),
                ) else {
                    skipped += 1;
                    continue;
                };
                let accepted: usize = j
                    .get("accepted")
                    .ok()
                    .and_then(|a| a.as_arr().ok())
                    .map(|a| a.iter().filter_map(|v| v.as_usize().ok()).sum())
                    .unwrap_or(0);
                // ragged rounds carry their drafted total (Σ s_i);
                // older dumps without the field fall back to the
                // uniform live * s
                let drafted = idx(&j, "drafted").unwrap_or(live * s);
                // clamp against malformed files: the identities assume
                // live <= width, drafted <= live*s, accepted <= drafted
                let live = live.min(width.max(1));
                let width = width.max(live);
                let drafted = drafted.min(live * s);
                let waste =
                    RoundWaste::from_ragged_round(width, live, s, drafted, accepted.min(drafted));
                surface.add_round(waste, 0.0, dur);
                last_cell = Some((WasteSurface::bucket_of(width), s));
            }
            "phase" => {
                let is_catch_up = j
                    .get("phase")
                    .ok()
                    .and_then(|p| p.as_str().ok().map(|s| s == "ssm_catch_up"))
                    .unwrap_or(false);
                if is_catch_up {
                    let dur = num(&j, "dur").unwrap_or(0.0);
                    catch_up_total += dur;
                    if let Some(cell) = last_cell {
                        if let Some(c) = surface.cells.get_mut(&cell) {
                            c.catch_up_s += dur;
                        }
                    }
                }
            }
            "finish" => {
                if j.get("shed").ok().and_then(|v| v.as_bool().ok()).unwrap_or(false) {
                    // shed waterfalls are queue-only; keep them out of
                    // the served-request component means
                    shed += 1;
                } else if let Ok(Some(w)) = j.get_opt("waterfall") {
                    if let Ok(wf) = Waterfall::from_json(w) {
                        finished.push(wf);
                    }
                }
            }
            "policy_fit" => {
                if let Ok(s) = j.get("snapshot") {
                    snapshot = Some(s.clone());
                }
            }
            "trigger" => {
                if let Ok(c) = j.get("cause").and_then(|v| Ok(v.as_str()?.to_string())) {
                    *triggers.entry(c).or_insert(0) += 1;
                }
            }
            _ => {}
        }
    }
    println!(
        "{path}: {events} events ({} finishes with waterfalls, {shed} shed, {skipped} skipped)",
        finished.len()
    );

    // --- 1. latency waterfalls ---
    if finished.is_empty() {
        println!("\nno finish waterfalls (re-run with --telemetry trace or --flight)");
    } else {
        let n = finished.len() as f64;
        let mut totals: Vec<f64> = finished.iter().map(|w| w.total()).collect();
        totals.sort_by(|a, b| a.total_cmp(b));
        let pct = |q: f64| totals[((totals.len() - 1) as f64 * q).round() as usize];
        println!(
            "\nlatency waterfall over {} requests (mean {:.4}s, p50 {:.4}s, p99 {:.4}s)",
            finished.len(),
            totals.iter().sum::<f64>() / n,
            pct(0.50),
            pct(0.99),
        );
        let mean_total = (totals.iter().sum::<f64>() / n).max(1e-12);
        let mut acc = Waterfall::default();
        for w in &finished {
            acc.queue += w.queue;
            acc.prefill += w.prefill;
            acc.catch_up += w.catch_up;
            acc.draft += w.draft;
            acc.verify += w.verify;
            acc.accept += w.accept;
            acc.reshape += w.reshape;
            acc.route_hop += w.route_hop;
            acc.other += w.other;
        }
        println!("{:>10} {:>12} {:>8}", "component", "mean s", "share");
        for (label, sum) in acc.components() {
            println!(
                "{label:>10} {:>12.6} {:>7.1}%",
                sum / n,
                100.0 * (sum / n) / mean_total
            );
        }
        let deferred: usize = finished.iter().map(|w| w.deferred_rounds).sum();
        if deferred > 0 {
            println!("{deferred} admission deferral rounds across finished requests");
        }
    }

    // --- 2. the waste surface ---
    if surface.cells.is_empty() {
        println!("\nno round events: the waste surface needs round spans");
    } else {
        let (mut committed, mut rejected, mut padding) = (0u64, 0u64, 0u64);
        for c in surface.cells.values() {
            committed += c.committed;
            rejected += c.rejected;
            padding += c.padding;
        }
        let slots = (committed + rejected + padding).max(1);
        println!(
            "\n{}totals: {committed} committed / {rejected} rejected / {padding} padding \
             of {slots} slots ({:.1}% goodput); ssm catch-up {catch_up_total:.4}s",
            surface.render(),
            100.0 * committed as f64 / slots as f64,
        );
    }

    // --- 3. policy audit ---
    if let Some(snap) = snapshot {
        if let Ok(Some(per_token)) = snap.get_opt("per_token") {
            if let Ok(obj) = per_token.as_obj() {
                if !obj.is_empty() {
                    println!(
                        "\npolicy audit (predicted vs realized per-token seconds)"
                    );
                    println!(
                        "{:>8} {:>13} {:>13} {:>8} {:>10}",
                        "bucket", "predicted", "realized", "err", "chosen s"
                    );
                    let mut rows: Vec<(usize, &Json)> = obj
                        .iter()
                        .filter_map(|(k, v)| k.parse::<usize>().ok().map(|b| (b, v)))
                        .collect();
                    rows.sort_by_key(|&(b, _)| b);
                    for (bucket, cell) in rows {
                        let realized = num(cell, "realized_s");
                        let predicted = num(cell, "predicted_s");
                        let chosen = snap
                            .get("chosen_s")
                            .ok()
                            .and_then(|c| idx(c, &bucket.to_string()));
                        let err = match (predicted, realized) {
                            (Some(p), Some(r)) if r > 0.0 => {
                                format!("{:>+7.1}%", 100.0 * (p - r) / r)
                            }
                            _ => format!("{:>8}", "-"),
                        };
                        println!(
                            "{bucket:>8} {:>13} {:>13} {err} {:>10}",
                            predicted.map_or("-".into(), |p| format!("{p:.6}")),
                            realized.map_or("-".into(), |r| format!("{r:.6}")),
                            chosen.map_or("-".into(), |s| s.to_string()),
                        );
                    }
                }
            }
        }
        if let Ok(Some(d)) = snap.get_opt("drift_flushes") {
            if let Ok(d) = d.as_usize() {
                if d > 0 {
                    println!("{d} CUSUM drift flushes");
                }
            }
        }
    }
    if !triggers.is_empty() {
        let list: Vec<String> =
            triggers.iter().map(|(c, n)| format!("{c} x{n}")).collect();
        println!("\nflight triggers: {}", list.join(", "));
    }
    Ok(())
}
