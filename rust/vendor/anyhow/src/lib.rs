//! Vendored, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline build environment carries no crates.io registry, so this
//! first-party shim provides the small API surface the workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.  Semantics follow the real
//! crate closely enough for drop-in use, with one deliberate deviation:
//! `Display` prints the whole context chain (`outer: ...: root cause`)
//! instead of only the outermost message, because the CLI prints errors
//! with plain `{e}`.

use std::error::Error as StdError;
use std::fmt;

/// A lightweight error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap the error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// that is what keeps this blanket conversion coherent (same trick as the
// real anyhow crate).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_joins_the_chain() {
        let e: Error = Error::from(io_err()).context("loading manifest");
        let s = e.to_string();
        assert!(s.starts_with("loading manifest"), "{s}");
        assert!(s.contains("missing file"), "{s}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);

        fn failing() -> Result<i32> {
            let n: i32 = "nope".parse()?;
            Ok(n)
        }
        assert!(failing().is_err());
    }

    #[test]
    fn with_context_works_on_both_error_kinds() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));

        let r2: Result<()> = Err(anyhow!("inner {}", 7));
        let e2 = r2.context("outer2").unwrap_err();
        assert_eq!(e2.to_string(), "outer2: inner 7");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
        assert!(f(101).unwrap_err().to_string().contains("too big"));
    }
}
