//! End-to-end engine correctness against the Python-generated goldens.
//!
//! `artifacts/goldens.json` (written by aot.py) holds greedy continuations
//! computed with the JAX reference engine.  Speculative decoding is
//! *lossless*: for any policy, the Rust engine must reproduce those exact
//! tokens.  This proves the whole chain — HLO executables, PJRT execution,
//! KV-cache state machine, acceptance rule — matches the L2 semantics.
//!
//! Requires a `--features pjrt` build and `make artifacts` (skipped
//! otherwise, loudly).  The artifact-free equivalents run on the stub
//! backend in the engine's unit tests and `tests/batcher_stub.rs`.
#![cfg(feature = "pjrt")]

use specbatch::engine::{Engine, EngineConfig};
use specbatch::policy::{Fixed, LutAdaptive, NoSpec, SpeculationPolicy};
use specbatch::runtime::Runtime;
use specbatch::scheduler::Lut;
use specbatch::util::json::Json;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts — run `make artifacts` first");
        None
    }
}

struct Golden {
    prompt: Vec<i32>,
    greedy: Vec<i32>,
    n_new: usize,
}

fn load_goldens(dir: &std::path::Path) -> Vec<Golden> {
    let json = Json::parse_file(dir.join("goldens.json")).expect("goldens parse");
    let n_new = json.get("n_new").unwrap().as_usize().unwrap();
    json.get("cases")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| Golden {
            prompt: c
                .get("prompt")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_i64().unwrap() as i32)
                .collect(),
            greedy: c
                .get("greedy")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_i64().unwrap() as i32)
                .collect(),
            n_new,
        })
        .collect()
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        // goldens were generated without EOS stopping
        stop_at_eos: false,
        record_acceptance: true,
        ..EngineConfig::default()
    }
}

#[test]
fn speculative_decoding_is_lossless_vs_python_goldens() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime");
    let mut engine = Engine::new(&rt, engine_cfg()).expect("engine");
    let goldens = load_goldens(&dir);
    assert!(!goldens.is_empty());
    let n_new = goldens[0].n_new;
    let prompts: Vec<Vec<i32>> = goldens.iter().map(|g| g.prompt.clone()).collect();

    // every policy must produce the identical greedy continuation
    let mut policies: Vec<(Option<usize>, Box<dyn SpeculationPolicy>)> = vec![
        (None, Box::new(NoSpec)),
        (Some(1), Box::new(Fixed(1))),
        (Some(3), Box::new(Fixed(3))),
        (Some(5), Box::new(Fixed(5))),
        (
            None,
            Box::new(LutAdaptive(
                Lut::new([(1, 4), (2, 3), (4, 3), (8, 2), (16, 1)].into_iter().collect())
                    .unwrap(),
            )),
        ),
    ];
    for (fixed_s, policy) in policies.iter_mut() {
        let label = policy.label();
        let out = engine
            .generate_batch(&prompts, n_new, policy.as_mut())
            .unwrap_or_else(|e| panic!("{label}: {e}"));
        for (i, g) in goldens.iter().enumerate() {
            assert_eq!(
                out.tokens[i], g.greedy,
                "policy {label} diverged from greedy on prompt {i}"
            );
        }
        if let Some(s) = fixed_s {
            assert!(out.stats.rounds > 0);
            assert!(
                out.stats.mean_accepted() >= 0.0
                    && out.stats.mean_accepted() <= *s as f64
            );
        }
    }
}

#[test]
fn batched_generation_matches_single_row_generation() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime");
    let mut engine = Engine::new(&rt, engine_cfg()).expect("engine");
    let goldens = load_goldens(&dir);
    let prompts: Vec<Vec<i32>> = goldens.iter().map(|g| g.prompt.clone()).collect();
    let n_new = 12;

    // batch of 4 (padded to bucket 4) vs each prompt alone (bucket 1):
    // batching must not change any row's output
    let batched = engine
        .generate_batch(&prompts, n_new, &mut Fixed(2))
        .expect("batched");
    for (i, p) in prompts.iter().enumerate() {
        let single = engine
            .generate_batch(std::slice::from_ref(p), n_new, &mut Fixed(2))
            .expect("single");
        assert_eq!(
            batched.tokens[i], single.tokens[0],
            "row {i}: batched != single"
        );
    }
}

#[test]
fn odd_batch_sizes_pad_to_bucket() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime");
    let mut engine = Engine::new(&rt, engine_cfg()).expect("engine");
    let goldens = load_goldens(&dir);
    let prompts: Vec<Vec<i32>> = goldens.iter().take(3).map(|g| g.prompt.clone()).collect();

    // 3 rows pad into the 4-bucket; outputs must match the goldens prefix
    let out = engine
        .generate_batch(&prompts, 8, &mut Fixed(3))
        .expect("gen");
    assert_eq!(out.tokens.len(), 3);
    for (i, g) in goldens.iter().take(3).enumerate() {
        assert_eq!(out.tokens[i], g.greedy[..8], "row {i}");
    }
}

#[test]
fn eos_stops_generation_early() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime");
    // pick a mid-continuation golden token as a fake EOS: generation must
    // stop right there
    let goldens = load_goldens(&dir);
    let fake_eos = goldens[0].greedy[3];
    let cfg = EngineConfig {
        stop_at_eos: true,
        eos_token: fake_eos,
        record_acceptance: false,
        ..EngineConfig::default()
    };
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    let out = engine
        .generate_batch(&[goldens[0].prompt.clone()], 16, &mut Fixed(2))
        .expect("gen");
    let toks = &out.tokens[0];
    let pos = toks.iter().position(|&t| t == fake_eos);
    assert!(pos.is_some(), "eos token never emitted");
    assert_eq!(pos.unwrap(), toks.len() - 1, "tokens continue past eos");
    assert_eq!(toks[..], goldens[0].greedy[..pos.unwrap() + 1]);
}

#[test]
fn rejects_oversized_prompts_and_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime");
    let mut engine = Engine::new(&rt, engine_cfg()).expect("engine");
    let max_prompt = rt.manifest.models["llm"].spec.max_prompt;
    let long = vec![1i32; max_prompt + 1];
    assert!(engine.generate_batch(&[long], 4, &mut NoSpec).is_err());
    assert!(engine.generate_batch(&[], 4, &mut NoSpec).is_err());
    let max_bucket = *rt.manifest.batch_buckets.iter().max().unwrap();
    let too_many = vec![vec![1i32, 5]; max_bucket + 1];
    assert!(engine.generate_batch(&too_many, 4, &mut NoSpec).is_err());
}

#[test]
fn kv_capacity_overflow_is_detected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime");
    let mut engine = Engine::new(&rt, engine_cfg()).expect("engine");
    let spec = &rt.manifest.models["llm"].spec;
    // ask for more tokens than the KV cache can hold: must error, not UB
    let budget = spec.max_seq;
    let out = engine.generate_batch(&[vec![1i32, 5, 9]], budget, &mut Fixed(2));
    assert!(out.is_err(), "expected KV overflow error");
    let msg = out.unwrap_err().to_string();
    assert!(msg.contains("overflow"), "unexpected error: {msg}");
}
