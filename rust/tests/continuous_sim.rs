//! The continuous-batching acceptance test: a deterministic
//! simulator-backed replay of the Fig. 5 stationary point (mean interval
//! 0.2 s, CV 1) under static vs continuous scheduling with the adaptive
//! policy.  Continuous batching must achieve strictly lower mean request
//! latency, and the per-round timeline must show the chosen `s` changing
//! as the live batch size changes *within a single serving epoch* — the
//! regime the paper's LUT was built for.

use std::collections::BTreeSet;

use specbatch::metrics::RoundEvent;
use specbatch::policy::{Fixed, LutAdaptive};
use specbatch::simulator::{simulate_trace, simulate_trace_continuous, simulated_lut, SimConfig};
use specbatch::testkit::harness::{paper_sim_config, ramp_prompt_pool, stationary_trace};
use specbatch::traffic::Trace;

fn paper_cfg() -> SimConfig {
    paper_sim_config(0)
}

fn fig5_trace() -> Trace {
    // prompt lengths sampled like the dataset's 4..24 range (fig5 bench)
    stationary_trace(&ramp_prompt_pool(4, 24), 400, 5, 0.2, 1.0)
}

/// One epoch's rounds must show s adapting to the live batch size.
fn epoch_with_adapting_s(rounds: &[RoundEvent]) -> Option<usize> {
    let epochs: BTreeSet<usize> = rounds.iter().map(|e| e.epoch).collect();
    for epoch in epochs {
        let in_epoch: Vec<&RoundEvent> = rounds.iter().filter(|e| e.epoch == epoch).collect();
        let lives: BTreeSet<usize> = in_epoch.iter().map(|e| e.live).collect();
        let specs: BTreeSet<usize> = in_epoch.iter().map(|e| e.s).collect();
        if lives.len() > 1 && specs.len() > 1 {
            return Some(epoch);
        }
    }
    None
}

#[test]
fn fig5_stationary_continuous_beats_static_and_s_adapts_within_an_epoch() {
    let cfg = paper_cfg();
    let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
    let mut policy = LutAdaptive(lut);
    let trace = fig5_trace();

    // one shared trace for both comparison points (paper methodology)
    let static_rec = simulate_trace(&cfg, &mut policy, &trace);
    let (cont_rec, rounds) = simulate_trace_continuous(&cfg, &mut policy, &trace);

    assert_eq!(static_rec.len(), trace.len());
    assert_eq!(cont_rec.len(), trace.len());

    // (a) strictly lower mean request latency under continuous batching
    let static_mean = static_rec.summary().mean;
    let cont_mean = cont_rec.summary().mean;
    assert!(
        cont_mean < static_mean,
        "continuous ({cont_mean:.3}s) must beat static ({static_mean:.3}s) \
         on the Fig. 5 stationary trace"
    );

    // (b) the per-round timeline shows s changing with the live batch
    //     size inside one serving epoch
    let epoch = epoch_with_adapting_s(&rounds);
    assert!(
        epoch.is_some(),
        "no epoch showed s adapting to the live batch size; rounds: {:?}",
        rounds.iter().take(32).collect::<Vec<_>>()
    );

    // sanity: the adaptation goes the right way — the largest s in the
    // adapting epoch belongs to a smaller live batch than the smallest s
    let epoch = epoch.unwrap();
    let in_epoch: Vec<&RoundEvent> = rounds.iter().filter(|e| e.epoch == epoch).collect();
    let max_s_round = in_epoch.iter().max_by_key(|e| e.s).unwrap();
    let min_s_round = in_epoch.iter().min_by_key(|e| e.s).unwrap();
    assert!(
        max_s_round.live <= min_s_round.live,
        "s should shrink as the live batch grows: s={} at live={} vs s={} at live={}",
        max_s_round.s,
        max_s_round.live,
        min_s_round.s,
        min_s_round.live
    );
}

#[test]
fn continuous_mode_is_deterministic_per_seed() {
    let cfg = paper_cfg();
    let trace = fig5_trace();
    let (a, rounds_a) = simulate_trace_continuous(&cfg, &mut Fixed(3), &trace);
    let (b, rounds_b) = simulate_trace_continuous(&cfg, &mut Fixed(3), &trace);
    let lat = |r: &specbatch::metrics::LatencyRecorder| {
        r.records().iter().map(|x| x.latency()).collect::<Vec<_>>()
    };
    assert_eq!(lat(&a), lat(&b));
    assert_eq!(rounds_a.len(), rounds_b.len());
}
