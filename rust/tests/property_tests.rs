//! Property-based tests (testkit substrate) over the pure logic of the
//! stack: acceptance rule, scheduler LUT, analytic model, queue
//! simulation, and the JSON substrate.  None of these need artifacts.

use std::collections::BTreeMap;

use specbatch::analytic::{AcceptanceModel, StepCostModel, TotalTimeModel};
use specbatch::dataset::Prompt;
use specbatch::engine::acceptance::{accept_batch, accept_row};
use specbatch::policy::{Fixed, LutAdaptive, ModelBased, NoSpec, SpeculationPolicy};
use specbatch::scheduler::Lut;
use specbatch::simulator::{simulate_trace, AcceptanceProcess, CostModel, GpuProfile,
    ModelProfile, SimConfig};
use specbatch::testkit::{check, Gen};
use specbatch::traffic::{Trace, TrafficPattern};
use specbatch::util::json::Json;
use specbatch::util::stats::{percentile, power_fit};

/// Pure-host mirror of Algorithm 1: with a deterministic next-token
/// oracle standing in for the LLM, speculative decoding with ANY draft
/// sequence must reproduce plain greedy decoding exactly, and every
/// round must commit at least one token.
#[test]
fn prop_speculative_loop_is_lossless_for_any_drafts() {
    check("spec loop lossless", 300, |g: &mut Gen| {
        let vocab = 32usize;
        // deterministic oracle: next = hash(last) % vocab
        let oracle =
            |last: i32| -> i32 { ((last as u64 * 2654435761 + 12345) % vocab as u64) as i32 };
        let start = g.int(0, vocab - 1) as i32;
        let n_new = g.int(1, 40);
        let s = g.int(1, 8);

        // ground truth: plain greedy chain
        let mut greedy = vec![start];
        for _ in 0..n_new {
            greedy.push(oracle(*greedy.last().unwrap()));
        }

        // speculative loop with an arbitrary (often wrong) draft model
        let mut committed = vec![start];
        let mut rounds = 0;
        while committed.len() - 1 < n_new {
            // drafts: mix of correct and random tokens
            let mut draft = Vec::with_capacity(s);
            let mut cur = *committed.last().unwrap();
            for _ in 0..s {
                let tok = if g.bool() {
                    oracle(cur) // correct draft
                } else {
                    g.int(0, vocab - 1) as i32 // junk draft
                };
                draft.push(tok);
                cur = tok;
            }
            // the LLM's argmax at each in-flight position
            let mut pred = Vec::with_capacity(s + 1);
            let mut prev = *committed.last().unwrap();
            pred.push(oracle(prev));
            for &d in &draft {
                prev = d;
                pred.push(oracle(prev));
            }
            let acc = accept_row(&draft, &pred);
            assert!(!acc.commit.is_empty(), "commit must be non-empty");
            committed.extend_from_slice(&acc.commit);
            rounds += 1;
            if rounds > 4 * (n_new + 2) {
                return false; // livelock
            }
        }
        committed.truncate(n_new + 1);
        committed == greedy[..n_new + 1]
    });
}

#[test]
fn prop_acceptance_commit_structure() {
    check("acceptance commit structure", 500, |g: &mut Gen| {
        let s = g.int(0, 8);
        let b = g.int(1, 8);
        let draft = g.tokens(b * s, b * s, 16);
        let pred = g.tokens(b * (s + 1), b * (s + 1), 16);
        let rows = accept_batch(&draft, &pred, b, s);
        rows.iter().enumerate().all(|(i, r)| {
            let d = &draft[i * s..(i + 1) * s];
            let p = &pred[i * (s + 1)..(i + 1) * (s + 1)];
            // commit = accepted prefix of drafts + one oracle token
            r.commit.len() == r.accepted + 1
                && r.commit[..r.accepted] == d[..r.accepted]
                && r.commit[r.accepted] == p[r.accepted]
                // accepted is exactly the first-mismatch index
                && d[..r.accepted].iter().zip(p).all(|(a, b)| a == b)
                && (r.accepted == s || d[r.accepted] != p[r.accepted])
        })
    });
}

#[test]
fn prop_lut_lookup_respects_paper_rule() {
    check("lut between-bucket rule", 300, |g: &mut Gen| {
        // random monotone bucket set with random s values
        let n = g.int(1, 6);
        let mut entries = BTreeMap::new();
        let mut b = 1usize;
        for _ in 0..n {
            entries.insert(b, g.int(0, 8));
            b *= 2;
        }
        let lut = Lut::new(entries.clone()).unwrap();
        let probe = g.int(1, 64);
        let got = lut.lookup(probe);
        let below = entries.range(..=probe).next_back().map(|(_, &s)| s);
        let above = entries.range(probe..).next().map(|(_, &s)| s);
        let expect = match (entries.get(&probe), below, above) {
            (Some(&s), _, _) => s,
            (None, Some(lo), Some(hi)) => lo.min(hi),
            (None, Some(lo), None) => lo,
            (None, None, Some(hi)) => hi,
            (None, None, None) => unreachable!(),
        };
        got == expect
    });
}

#[test]
fn prop_policy_never_exceeds_available_executables() {
    check("policy caps at max_s", 300, |g: &mut Gen| {
        let max_s = g.int(0, 8);
        let batch = g.int(1, 32);
        let policy: Box<dyn SpeculationPolicy> = match g.int(0, 3) {
            0 => Box::new(NoSpec),
            1 => Box::new(Fixed(g.int(0, 12))),
            2 => {
                let mut e = BTreeMap::new();
                e.insert(1, g.int(0, 12));
                e.insert(8, g.int(0, 12));
                Box::new(LutAdaptive(Lut::new(e).unwrap()))
            }
            _ => {
                let mut e = BTreeMap::new();
                e.insert(1, g.int(0, 12));
                e.insert(16, g.int(0, 12));
                Box::new(ModelBased::new(Lut::new(e).unwrap()))
            }
        };
        policy.choose(batch, max_s) <= max_s
    });
}

/// The paper's key claim, asserted through the ONLINE policy: for any
/// fitted acceptance model with gamma < 1 and per-bucket step costs whose
/// alpha' is non-decreasing in the bucket (the Fig. 3 premise),
/// `ModelBased::choose` is non-increasing in the live batch size.
#[test]
fn prop_model_based_choose_non_increasing_in_live_batch() {
    check("model-based choose monotone in live", 150, |g: &mut Gen| {
        let acceptance = AcceptanceModel {
            c: g.f64(0.3, 1.0),
            gamma: g.f64(0.1, 0.95), // gamma < 1: the Eq. 6 regime
            r2: 1.0,
        };
        let beta = g.f64(0.005, 0.05);
        // sparse or dense fitted-bucket sets both must stay monotone
        let buckets: Vec<usize> = if g.bool() {
            vec![1, 2, 4, 8, 16, 32, 64]
        } else {
            vec![1, 4, 16, 64]
        };
        let mut alpha = g.f64(1e-5, 5e-4);
        let costs: Vec<StepCostModel> = buckets
            .iter()
            .map(|&b| {
                let m = StepCostModel {
                    batch: b,
                    alpha,
                    beta,
                    t_ssm: 0.0, // folded into alpha, as the online fit does
                    r2: 1.0,
                };
                alpha *= 1.0 + g.f64(0.0, 2.0);
                m
            })
            .collect();
        let fallback = Lut::new([(1usize, 4usize)].into_iter().collect()).unwrap();
        let policy = ModelBased::with_models(fallback, acceptance, &costs);
        let mut last = usize::MAX;
        for live in 1..=64usize {
            let s = policy.choose(live, 8);
            if s > last {
                return false;
            }
            last = s;
        }
        true
    });
}

#[test]
fn prop_analytic_sopt_monotone_in_alpha() {
    check("s_opt non-increasing in alpha", 200, |g: &mut Gen| {
        let acceptance = AcceptanceModel {
            c: g.f64(0.3, 1.0),
            gamma: g.f64(0.2, 0.9),
            r2: 1.0,
        };
        let beta = g.f64(0.005, 0.05);
        let t_ssm = g.f64(0.0001, 0.004);
        let mut last = usize::MAX;
        for i in 0..5 {
            let alpha = 1e-4 * (4.0f64).powi(i);
            let m = TotalTimeModel {
                acceptance,
                cost: StepCostModel {
                    batch: 1 << i,
                    alpha,
                    beta,
                    t_ssm,
                    r2: 1.0,
                },
            };
            let s = m.s_opt(8);
            if s > last {
                return false;
            }
            last = s;
        }
        true
    });
}

#[test]
fn prop_acceptance_process_expectation_matches_samples() {
    check("acceptance process calibration", 30, |g: &mut Gen| {
        let proc_ = if g.bool() {
            AcceptanceProcess::Geometric { q: g.f64(0.2, 0.95) }
        } else {
            AcceptanceProcess::PowerLaw {
                c: g.f64(0.4, 1.0),
                gamma: g.f64(0.3, 0.9),
            }
        };
        let s = g.int(1, 8);
        let mut rng = specbatch::util::prng::Pcg64::new(g.int(0, 1 << 30) as u64);
        let n = 30_000;
        let emp: f64 = (0..n).map(|_| proc_.sample(s, &mut rng)).sum::<usize>() as f64 / n as f64;
        (emp - proc_.expected_accepted(s)).abs() < 0.06
    });
}

#[test]
fn prop_simulated_queue_conserves_requests_in_fifo_order() {
    check("queue conservation + FIFO", 40, |g: &mut Gen| {
        let cfg = {
            let mut c = SimConfig::paper_default(
                CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
                CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
            );
            c.max_new_tokens = g.int(4, 32);
            c.max_batch = g.int(1, 16);
            c
        };
        let pool = vec![Prompt { ids: vec![1; g.int(2, 24)], text: String::new() }];
        let n = g.int(1, 120);
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: g.f64(0.01, 1.0),
                cv: g.f64(0.3, 5.0),
            },
            &pool,
            n,
            g.int(0, 1 << 30) as u64,
        );
        let rec = simulate_trace(&cfg, &mut Fixed(g.int(1, 6)), &trace);
        if rec.len() != n {
            return false;
        }
        // FIFO: start times non-decreasing in request id
        let mut by_id: Vec<_> = rec.records().to_vec();
        by_id.sort_by_key(|r| r.id);
        by_id.windows(2).all(|w| w[1].started_at >= w[0].started_at - 1e-12)
            && by_id.iter().all(|r| {
                r.started_at >= r.sent_at - 1e-12 && r.finished_at > r.started_at
            })
    });
}

/// Random deadlined traffic through the continuous DES under every
/// admission controller: every request leaves exactly one record, and
/// the attainment counters conserve — `met + missed + shed == n` when
/// every request carries a deadline (completed + shed == n always).
#[test]
fn prop_admission_attainment_counters_conserve() {
    use specbatch::admission::build_controller;
    use specbatch::config::AdmissionSpec;
    use specbatch::simulator::simulate_trace_continuous_admission;
    use specbatch::testkit::harness::warm_model_based;
    use specbatch::traffic::SloSpec;

    check("attainment conservation", 24, |g: &mut Gen| {
        let cfg = {
            let mut c = SimConfig::paper_default(
                CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
                CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
            );
            c.max_new_tokens = g.int(4, 32);
            c
        };
        let pool = vec![Prompt { ids: vec![1; g.int(2, 16)], text: String::new() }];
        let n = g.int(1, 100);
        let seed = g.int(0, 1 << 30) as u64;
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: g.f64(0.005, 0.5),
                cv: g.f64(0.3, 3.0),
            },
            &pool,
            n,
            seed,
        )
        .with_deadlines(&SloSpec::new(g.f64(0.05, 3.0), g.f64(1.0, 4.0)), seed);
        AdmissionSpec::all().into_iter().all(|spec| {
            let mut policy = warm_model_based(&cfg, 24);
            let mut ctrl = build_controller(spec);
            let (rec, _) = simulate_trace_continuous_admission(
                &cfg,
                &mut policy,
                ctrl.as_mut(),
                &trace,
            );
            let s = rec.slo_attainment();
            let mut ids: Vec<u64> = rec.records().iter().map(|r| r.id).collect();
            ids.sort_unstable();
            ids == (0..n as u64).collect::<Vec<u64>>()
                && s.deadlined == n
                && s.met + s.missed + s.shed == n
                && s.completed + s.shed == n
                && (spec != AdmissionSpec::Fifo || s.shed == 0)
        })
    });
}

#[test]
fn prop_json_roundtrips_random_documents() {
    fn random_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.int(0, 3) } else { g.int(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = g.int(0, 12);
                Json::Str((0..n).map(|_| char::from(g.int(32, 126) as u8)).collect())
            }
            4 => Json::Arr((0..g.int(0, 4)).map(|_| random_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.int(0, 4))
                    .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json roundtrip", 300, |g: &mut Gen| {
        let v = random_json(g, 3);
        Json::parse(&v.compact()).map(|p| p == v).unwrap_or(false)
            && Json::parse(&v.pretty()).map(|p| p == v).unwrap_or(false)
    });
}

#[test]
fn prop_percentile_within_sample_bounds() {
    check("percentile bounds", 300, |g: &mut Gen| {
        let n = g.int(1, 100);
        let xs: Vec<f64> = (0..n).map(|_| g.f64(-1e3, 1e3)).collect();
        let q = g.f64(0.0, 100.0);
        let p = percentile(&xs, q);
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        p >= lo - 1e-9 && p <= hi + 1e-9
    });
}

#[test]
fn prop_power_fit_recovers_exact_curves() {
    check("power fit recovery", 200, |g: &mut Gen| {
        let c = g.f64(0.1, 5.0);
        let gamma = g.f64(-1.5, 1.5);
        let xs: Vec<f64> = (1..=10).map(|x| x as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| c * x.powf(gamma)).collect();
        let (cf, gf, r2) = power_fit(&xs, &ys);
        (cf - c).abs() < 1e-6 && (gf - gamma).abs() < 1e-6 && (r2 - 1.0).abs() < 1e-9
    });
}

#[test]
fn prop_gamma_samples_positive_with_any_cv() {
    check("gamma positivity", 200, |g: &mut Gen| {
        let mut rng = specbatch::util::prng::Pcg64::new(g.int(0, 1 << 30) as u64);
        let gi = specbatch::util::prng::GammaIntervals::new(
            g.f64(0.01, 2.0),
            g.f64(0.1, 6.0),
        );
        (0..200).all(|_| gi.sample(&mut rng) > 0.0)
    });
}
