//! SLO-admission acceptance tests.
//!
//! Two pins, per the admission subsystem's contract:
//!
//! 1. **FIFO is the legacy behaviour, bit for bit** — the refactor routed
//!    every driver (batcher, DES static, DES continuous) through the
//!    `AdmissionController` seam, and with the `Fifo` controller each
//!    must reproduce the pre-subsystem outputs exactly: tokens, rounds,
//!    acceptance structure, latencies.
//! 2. **SloAware beats Fifo on SLO attainment** on a bursty Fig. 6-style
//!    overload trace, by a pinned margin across ≥3 seeds, in the DES and
//!    in the threaded stub server.  The mechanism: under overload FIFO
//!    burns rounds completing requests that are already doomed, dragging
//!    feasible requests past their deadlines too; `SloAware` sheds the
//!    doomed ones (they were going to miss either way — shed or served)
//!    and serves the urgent feasible ones first.
//!
//! Plus the shed-requests-never-touch-KV property under both layouts.

use specbatch::admission::{
    AdmissionController, AdmissionView, Candidate, Edf, Fifo, SloAware, Verdict,
};
use specbatch::batcher::{BatchRequest, BatcherConfig, ContinuousBatcher};
use specbatch::config::{AdmissionSpec, PolicySpec};
use specbatch::engine::{Engine, EngineConfig};
use specbatch::kvcache::KvLayout;
use specbatch::metrics::LatencyRecorder;
use specbatch::policy::Fixed;
use specbatch::server::{run_experiment, Backend, SchedulingMode, ServerConfig};
use specbatch::simulator::{
    simulate_trace, simulate_trace_admission, simulate_trace_continuous,
    simulate_trace_continuous_admission,
};
use specbatch::testkit::harness::{
    assert_slo_conserves, const_prompt_pool, llm_chain, paper_sim_config, slo_fig6_trace,
    stationary_trace, stub_prompt_pool, warm_model_based,
};
use specbatch::testkit::stub::StubSpec;
use specbatch::traffic::{SloSpec, Trace, TraceItem};

fn lat_key(rec: &LatencyRecorder) -> Vec<(u64, bool, f64)> {
    let mut v: Vec<(u64, bool, f64)> = rec
        .records()
        .iter()
        .map(|r| (r.id, r.shed, r.latency()))
        .collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v
}

/// The refactored DES under the `Fifo` controller reproduces the legacy
/// entry points bit for bit — on deadline-free AND deadlined traces (a
/// deadline must be inert under FIFO), static and continuous.
#[test]
fn fifo_controller_is_bit_identical_to_the_legacy_des() {
    for seed in [0u64, 5, 9] {
        let mut cfg = paper_sim_config(seed);
        cfg.max_new_tokens = 32;
        let plain = stationary_trace(&const_prompt_pool(12), 200, seed, 0.1, 1.0);
        let deadlined = plain.with_deadlines(&SloSpec::new(1.0, 2.0), seed);
        for trace in [&plain, &deadlined] {
            let (legacy, legacy_rounds) =
                simulate_trace_continuous(&cfg, &mut Fixed(2), trace);
            let (via_ctrl, ctrl_rounds) =
                simulate_trace_continuous_admission(&cfg, &mut Fixed(2), &mut Fifo, trace);
            assert_eq!(lat_key(&legacy), lat_key(&via_ctrl), "continuous seed {seed}");
            assert_eq!(legacy_rounds.len(), ctrl_rounds.len());
            for (a, b) in legacy_rounds.iter().zip(&ctrl_rounds) {
                assert_eq!(a, b, "round diverged at seed {seed}");
            }

            let legacy_static = simulate_trace(&cfg, &mut Fixed(2), trace);
            let static_ctrl =
                simulate_trace_admission(&cfg, &mut Fixed(2), &mut Fifo, trace);
            assert_eq!(lat_key(&legacy_static), lat_key(&static_ctrl), "static seed {seed}");
        }
        // deadlines are inert under FIFO: the deadlined replay matches the
        // plain replay on every latency
        let (a, _) = simulate_trace_continuous(&cfg, &mut Fixed(2), &plain);
        let (b, _) = simulate_trace_continuous(&cfg, &mut Fixed(2), &deadlined);
        let strip = |v: Vec<(u64, bool, f64)>| -> Vec<(u64, f64)> {
            v.into_iter().map(|(id, _, l)| (id, l)).collect()
        };
        assert_eq!(strip(lat_key(&a)), strip(lat_key(&b)));
    }
}

/// The refactored batcher under `Fifo` is the legacy batcher bit for bit
/// on the stub engine: identical tokens, rounds, and acceptance timeline.
#[test]
fn fifo_batcher_matches_legacy_batcher_bit_for_bit() {
    let drive = |mut batcher: ContinuousBatcher| {
        let mut engine = Engine::stub(StubSpec::default(), EngineConfig::default()).unwrap();
        let mut policy = Fixed(3);
        // staggered arrivals force admissions, a reshape, and retirement
        let mut pending: Vec<(usize, BatchRequest)> = (0..10u64)
            .map(|i| {
                let mut req = BatchRequest::new(i, vec![5 + i as i32, 7], i as f64 * 1e-3);
                req.deadline = Some(1e9); // inert under FIFO
                ((i as usize) * 2, req)
            })
            .collect();
        let mut finished = Vec::new();
        let mut step = 0usize;
        while batcher.has_work() || !pending.is_empty() {
            pending.retain(|(at, req)| {
                if *at <= step {
                    batcher.enqueue(req.clone());
                    false
                } else {
                    true
                }
            });
            finished.extend(
                batcher
                    .step(&mut engine, &mut policy, step as f64 * 1e-3)
                    .unwrap(),
            );
            step += 1;
            assert!(step < 10_000);
        }
        assert!(batcher.take_shed().is_empty(), "FIFO never sheds");
        assert_eq!(batcher.admission_totals(), (0, 0), "FIFO never defers");
        let mut out: Vec<(u64, Vec<i32>, f64)> = finished
            .into_iter()
            .map(|f| (f.id, f.tokens, f.admitted_at))
            .collect();
        out.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let timeline: Vec<(usize, usize, usize)> = batcher
            .timeline
            .iter()
            .map(|e| (e.live, e.s, e.accepted))
            .collect();
        (out, timeline)
    };
    let cfg = BatcherConfig {
        max_batch: 4,
        max_new_tokens: 10,
    };
    let legacy = drive(ContinuousBatcher::new(cfg.clone()));
    let via_ctrl = drive(ContinuousBatcher::with_admission(cfg, Box::new(Fifo)));
    assert_eq!(legacy, via_ctrl);
    // and the tokens are the exact greedy chains (losslessness)
    for (id, tokens, _) in &legacy.0 {
        assert_eq!(
            tokens,
            &llm_chain(&StubSpec::default(), 7, 10),
            "request {id} diverged"
        );
    }
}

/// The payoff, in the DES: on a time-compressed Fig. 6 overload trace
/// with per-request deadlines, `SloAware` admission (driven by a warm
/// model-based policy's `predict_token_time`) beats `Fifo` on SLO
/// attainment by a pinned margin across three seeds.  Margins were
/// validated against an exact-PRNG Python mirror of this DES: measured
/// gaps are +0.21 / +0.30 / +0.30 at these seeds — the 0.08 pin has
/// better than 2.5x headroom.
#[test]
fn slo_aware_beats_fifo_on_attainment_in_the_des() {
    for seed in [2u64, 3, 4] {
        let mut cfg = paper_sim_config(seed);
        cfg.max_new_tokens = 32;
        let trace = slo_fig6_trace(&const_prompt_pool(12), 400, seed, 0.1, 1.5, 2.0);

        let mut fifo_policy = warm_model_based(&cfg, 30);
        let (fifo_rec, _) =
            simulate_trace_continuous_admission(&cfg, &mut fifo_policy, &mut Fifo, &trace);
        assert_slo_conserves(&fifo_rec, 400);
        let fifo = fifo_rec.slo_attainment();
        assert_eq!(fifo.shed, 0, "FIFO never sheds");

        let mut slo_policy = warm_model_based(&cfg, 30);
        let mut ctrl = SloAware::default();
        let (slo_rec, _) =
            simulate_trace_continuous_admission(&cfg, &mut slo_policy, &mut ctrl, &trace);
        assert_slo_conserves(&slo_rec, 400);
        let slo = slo_rec.slo_attainment();
        assert!(
            slo.shed > 0,
            "overload must force sheds (seed {seed}): {slo:?}"
        );

        let gap = slo.attainment() - fifo.attainment();
        assert!(
            gap >= 0.08,
            "SloAware must beat Fifo by >= 0.08 attainment at seed {seed}: \
             slo {:.3} vs fifo {:.3} (gap {gap:+.3})",
            slo.attainment(),
            fifo.attainment()
        );
        assert!(
            slo.attainment() >= 0.25,
            "SloAware attainment collapsed at seed {seed}: {:.3}",
            slo.attainment()
        );
    }
}

/// The payoff, on the real threaded stub server: a burst of lax-deadline
/// requests followed by urgent ones.  FIFO serves in arrival order, so
/// the urgent requests sit behind the whole lax backlog and miss; EDF
/// ordering (what `SloAware` degrades to under a static policy, whose
/// `predict_token_time` is `None`) serves them first and meets every
/// deadline.  Timing is pinned, not hoped for: `Fixed(0)` commits
/// exactly one token per round and the engine's `min_round_seconds`
/// throttle fixes the round at 2 ms, so a request takes ~10 ms of
/// service on any machine.  Urgent requests under SloAware finish by
/// ~40 ms against a 90 ms budget (≥ 50 ms of scheduler-jitter headroom);
/// under FIFO they wait out 48 lax requests (~120 ms) and miss by
/// ≥ 30 ms — and every source of slowness (startup, stalls, oversleep)
/// only widens the FIFO miss.
#[test]
fn slo_aware_beats_fifo_in_the_threaded_stub_server() {
    const LAX: usize = 48;
    const URGENT: usize = 12;
    const URGENT_BUDGET: f64 = 0.090;

    let burst_trace = |seed: u64| -> Trace {
        let pool = stub_prompt_pool();
        let items = (0..LAX + URGENT)
            .map(|k| {
                let urgent = k >= LAX;
                let send_at = if urgent {
                    0.004 + (k - LAX) as f64 * 1e-4
                } else {
                    k as f64 * 1e-4
                };
                let budget = if urgent { URGENT_BUDGET } else { 30.0 };
                TraceItem {
                    id: k as u64,
                    send_at,
                    deadline: Some(send_at + budget),
                    class: 0,
                    prompt: pool[(k + seed as usize) % pool.len()].clone(),
                }
            })
            .collect();
        Trace { items }
    };

    let run = |admission: AdmissionSpec, seed: u64| {
        let cfg = ServerConfig {
            max_batch: 4,
            max_new_tokens: 6,
            mode: SchedulingMode::Continuous,
            admission,
            engine: EngineConfig {
                // pin the service rate: 2 ms per decode round, exactly
                // one committed token per round under Fixed(0)
                min_round_seconds: 2e-3,
                ..EngineConfig::default()
            },
            ..ServerConfig::default()
        };
        let out = run_experiment(
            Backend::Stub(StubSpec::default()),
            cfg,
            PolicySpec::Fixed(0),
            None,
            &burst_trace(seed),
        )
        .expect("experiment");
        assert_slo_conserves(&out.recorder, LAX + URGENT);
        out.recorder.slo_attainment()
    };

    for seed in [1u64, 2, 3] {
        let fifo = run(AdmissionSpec::Fifo, seed);
        let slo = run(AdmissionSpec::SloAware, seed);
        let gap = slo.attainment() - fifo.attainment();
        assert!(
            gap >= 0.10,
            "threaded server: SloAware must beat Fifo by >= 0.10 at seed {seed}: \
             slo {:.3} vs fifo {:.3} (slo: {slo:?}, fifo: {fifo:?})",
            slo.attainment(),
            fifo.attainment()
        );
    }
}

/// A controller that sheds every third request — exercises the
/// shed-never-touches-KV property deterministically.
struct ShedThirds;

impl AdmissionController for ShedThirds {
    fn plan(&mut self, queue: &[Candidate], _view: &AdmissionView<'_>) -> Vec<(usize, Verdict)> {
        queue
            .iter()
            .enumerate()
            .map(|(i, c)| {
                if c.id % 3 == 2 {
                    (i, Verdict::Shed)
                } else {
                    (i, Verdict::Admit)
                }
            })
            .collect()
    }

    fn label(&self) -> String {
        "shed-thirds".into()
    }
}

/// Shed requests never occupy a batch row, never consume KV blocks, and
/// the block pools stay leak-free — under both KV layouts.
#[test]
fn shed_requests_never_occupy_kv_blocks() {
    for layout in [KvLayout::Dense, KvLayout::Paged] {
        let mut engine = Engine::stub(
            StubSpec::default(),
            EngineConfig {
                kv_layout: layout,
                ..EngineConfig::default()
            },
        )
        .unwrap();
        let mut policy = Fixed(3);
        let mut batcher = ContinuousBatcher::with_admission(
            BatcherConfig {
                max_batch: 4,
                max_new_tokens: 10,
            },
            Box::new(ShedThirds),
        );
        for i in 0..12u64 {
            batcher.enqueue(BatchRequest::new(i, vec![5 + i as i32, 9], 0.0));
        }
        let mut finished = Vec::new();
        let mut step = 0usize;
        while batcher.has_work() {
            finished.extend(
                batcher
                    .step(&mut engine, &mut policy, step as f64 * 1e-3)
                    .unwrap(),
            );
            step += 1;
            assert!(step < 10_000);
        }
        let shed = batcher.take_shed();
        assert_eq!(shed.len(), 4, "ids 2, 5, 8, 11 shed");
        assert!(shed.iter().all(|s| s.id % 3 == 2));
        assert_eq!(finished.len(), 8);
        for f in &finished {
            assert_ne!(f.id % 3, 2, "a shed request produced tokens");
            assert_eq!(f.tokens, llm_chain(&StubSpec::default(), 9, 10));
        }
        let (_, sheds) = batcher.admission_totals();
        assert_eq!(sheds, 4);
        if layout == KvLayout::Paged {
            engine.clear_prefix_cache(); // cached prefix blocks are not leaks
            let stats = engine.kv_block_stats().expect("paged engine");
            assert!(stats.is_leak_free(), "blocks leaked under {layout:?}: {stats:?}");
        } else {
            assert!(engine.kv_block_stats().is_none());
        }
    }
}

/// `Edf` admission order is a permutation of the queue that respects
/// deadlines (every deadlined candidate before every later-deadlined one,
/// all deadlined before all deadline-less, arrival order within ties).
#[test]
fn edf_plan_is_a_deadline_respecting_permutation() {
    let pool = const_prompt_pool(6);
    for seed in [3u64, 8, 21] {
        let trace = stationary_trace(&pool, 64, seed, 0.05, 2.0)
            .with_deadlines(&SloSpec::new(1.0, 4.0), seed);
        // half the queue loses its deadline, so both classes appear
        let queue: Vec<Candidate> = trace
            .items
            .iter()
            .enumerate()
            .map(|(i, item)| Candidate {
                id: item.id,
                sent_at: item.send_at,
                deadline: if i % 2 == 0 { item.deadline } else { None },
                prompt_len: item.prompt.ids.len(),
                tokens_left: 32,
                deferred: 0,
            })
            .collect();
        let view = AdmissionView {
            now: 0.0,
            live: 0,
            max_batch: 16,
            policy: &Fixed(2),
        };
        let plan = Edf.plan(&queue, &view);
        assert_eq!(plan.len(), queue.len());
        let mut seen = vec![false; queue.len()];
        for &(i, v) in &plan {
            assert_eq!(v, Verdict::Admit, "EDF never defers or sheds");
            assert!(!std::mem::replace(&mut seen[i], true), "index {i} repeated");
        }
        for w in plan.windows(2) {
            let (a, b) = (&queue[w[0].0], &queue[w[1].0]);
            let ka = a.deadline.unwrap_or(f64::INFINITY);
            let kb = b.deadline.unwrap_or(f64::INFINITY);
            assert!(
                ka < kb || (ka == kb && w[0].0 < w[1].0),
                "EDF order violated: {:?} before {:?}",
                (w[0].0, ka),
                (w[1].0, kb)
            );
        }
    }
}
