//! The ragged-speculation payoff scenario (ROADMAP item 1) plus the
//! uniform-regime identity guarantee.
//!
//! **Payoff**: a mixed-domain trace interleaves two acceptance regimes
//! in one continuous batch — class 0 (3 of every 4 requests) drafts
//! land often (geometric q = 0.75), class 1 almost never (q = 0.05).
//! No uniform speculation length serves both: any `s` that helps
//! class 0 burns draft and verify slots on class 1, and `s` small
//! enough to protect class 1 starves class 0.  The ragged model-based
//! policy learns a private acceptance curve per class and chooses
//! per-row lengths (class 0 ≈ 2, class 1 = 0 at steady state), which
//! must strictly beat EVERY uniform policy on mean per-token latency.
//!
//! The scenario is decode-dominated on purpose: 600-token prompts and
//! 512 generated tokens keep the verify pass memory-bound (KV reads
//! dominate) across the `s` range class 0 uses, so per-row draft
//! lengths — not prefill or the padded verify width — decide the
//! margin.
//!
//! **Identity**: a batch where every row shares one class (ANY class
//! value) must reproduce the classless uniform policy bit for bit —
//! same records, same round timeline.

use std::collections::BTreeMap;

use specbatch::dataset::Prompt;
use specbatch::policy::{Fixed, ModelBased, ModelBasedConfig, NoSpec, SpeculationPolicy};
use specbatch::scheduler::Lut;
use specbatch::simulator::{
    simulate_trace_continuous, AcceptanceProcess, CostModel, GpuProfile, ModelProfile, SimConfig,
};
use specbatch::traffic::{Trace, TrafficPattern};

const PROMPT_LEN: usize = 600;
const N_REQUESTS: usize = 100;
const MAX_NEW: usize = 512;
const INTERVAL: f64 = 1.3;

/// OPT-6.7B target + OPT-1.3B draft on RTX3090 — the paper's main pair
/// — with the two-regime class map.
fn mixed_cfg(seed: u64) -> SimConfig {
    let llm = CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090);
    let ssm = CostModel::new(ModelProfile::OPT_1_3B, GpuProfile::RTX3090);
    let mut cfg = SimConfig::paper_default(llm, ssm);
    cfg.max_new_tokens = MAX_NEW;
    cfg.class_acceptance
        .insert(0, AcceptanceProcess::Geometric { q: 0.75 });
    cfg.class_acceptance
        .insert(1, AcceptanceProcess::Geometric { q: 0.05 });
    cfg.seed = seed;
    cfg
}

/// 3:1 class mix: every 4th request is the low-acceptance domain.  The
/// skew matters — low-acceptance rows commit one token per round, so
/// they linger and the *live* batch converges to roughly half and half.
fn mixed_trace(seed: u64) -> Trace {
    let pool = vec![Prompt {
        ids: vec![1; PROMPT_LEN],
        text: String::new(),
    }];
    let mut trace = Trace::generate(
        &TrafficPattern::Stationary {
            interval: INTERVAL,
            cv: 1.0,
        },
        &pool,
        N_REQUESTS,
        seed,
    );
    for item in &mut trace.items {
        item.class = if item.id % 4 == 3 { 1 } else { 0 };
    }
    trace
}

/// The offline LUT an operator would have profiled for the *blended*
/// workload — the model-based policy's cold-start fallback.
fn profiled_lut() -> Lut {
    Lut::new(BTreeMap::from([(1, 4), (2, 4), (4, 3), (8, 2), (16, 2)])).unwrap()
}

fn ragged_policy() -> ModelBased {
    // slower probe cadence: the probe's job here is keeping the
    // per-class curves identifiable, and every class-0 probe executes
    // a round one step past the committed choice
    ModelBased::with_config(
        profiled_lut(),
        ModelBasedConfig {
            explore_every: 32,
            ..ModelBasedConfig::default()
        },
    )
}

fn mean_per_token(cfg: &SimConfig, policy: &mut dyn SpeculationPolicy, trace: &Trace) -> f64 {
    let (rec, _) = simulate_trace_continuous(cfg, policy, trace);
    assert_eq!(rec.len(), trace.len(), "request conservation");
    rec.mean_per_token_latency()
}

#[test]
fn ragged_model_based_beats_every_uniform_s_on_a_mixed_domain_trace() {
    for seed in [2u64, 3, 4] {
        let cfg = mixed_cfg(seed);
        let trace = mixed_trace(seed);

        let ragged = mean_per_token(&cfg, &mut ragged_policy(), &trace);

        let mut uniforms: Vec<(String, f64)> =
            vec![("no-spec".into(), mean_per_token(&cfg, &mut NoSpec, &trace))];
        for s in 1..=4usize {
            uniforms.push((
                format!("fixed-{s}"),
                mean_per_token(&cfg, &mut Fixed(s), &trace),
            ));
        }

        for (name, uniform) in &uniforms {
            assert!(
                ragged < *uniform,
                "seed {seed}: ragged model-based ({:.3} ms/tok) should beat \
                 uniform {name} ({:.3} ms/tok)",
                ragged * 1e3,
                uniform * 1e3,
            );
        }
    }
}

#[test]
fn the_payoff_run_actually_exercises_ragged_rounds() {
    let cfg = mixed_cfg(2);
    let trace = mixed_trace(2);
    let (_, rounds) = simulate_trace_continuous(&cfg, &mut ragged_policy(), &trace);
    // a ragged round drafts fewer tokens than the padded rectangle
    // `live * s_max` would imply
    let ragged_rounds = rounds
        .iter()
        .filter(|r| r.s > 0 && r.drafted < r.live * r.s)
        .count();
    assert!(
        ragged_rounds > 100,
        "expected a substantial share of ragged rounds, got {ragged_rounds} of {}",
        rounds.len()
    );
    // and the generalized waste identity holds on every one of them
    for r in rounds.iter().filter(|r| r.s > 0) {
        assert!(r.drafted <= r.live * r.s, "drafted exceeds the rectangle");
        assert!(r.accepted <= r.drafted, "accepted exceeds drafted");
    }
}

/// A single-class batch must recover the uniform policy bit for bit,
/// regardless of WHICH class value tags the rows: same per-request
/// records, same round timeline.  This pins the broadcast short-circuit
/// in `choose_ragged` AND the per-class observation plumbing (feeding
/// class windows must not perturb the uniform decision path).
#[test]
fn single_class_batches_recover_the_uniform_policy_bit_for_bit() {
    let llm = CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090);
    let ssm = CostModel::new(ModelProfile::OPT_1_3B, GpuProfile::RTX3090);
    let mut base = SimConfig::paper_default(llm, ssm);
    base.max_new_tokens = 64;
    base.seed = 7;

    let pool = vec![Prompt {
        ids: vec![1; 32],
        text: String::new(),
    }];
    let classless = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.05,
            cv: 1.0,
        },
        &pool,
        60,
        7,
    );
    // identical schedule, every row tagged class 1, with class 1 mapped
    // to the same acceptance process the classless run blends to
    let mut tagged = classless.clone();
    for item in &mut tagged.items {
        item.class = 1;
    }
    let mut tagged_cfg = base.clone();
    tagged_cfg
        .class_acceptance
        .insert(1, base.acceptance.clone());

    let policies: Vec<(&str, fn() -> Box<dyn SpeculationPolicy>)> = vec![
        ("fixed-2", || Box::new(Fixed(2))),
        ("model-based", || Box::new(ModelBased::new(profiled_lut()))),
    ];
    for (name, mk) in policies {
        let (rec_a, rounds_a) = simulate_trace_continuous(&base, mk().as_mut(), &classless);
        let (rec_b, rounds_b) = simulate_trace_continuous(&tagged_cfg, mk().as_mut(), &tagged);
        assert_eq!(
            rec_a.records(),
            rec_b.records(),
            "{name}: classless vs single-class records diverged"
        );
        assert_eq!(
            rounds_a, rounds_b,
            "{name}: classless vs single-class round timelines diverged"
        );
    }
}
