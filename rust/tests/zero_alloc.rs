//! The tentpole invariant, asserted directly: once the round-scratch
//! arenas reach their high-water mark, steady-state `decode_round` calls
//! perform **zero heap allocations** — no Vec churn in the feed/draft/
//! commit staging, no per-row boxing, no accepted-count clones, no
//! stopwatch inserts.
//!
//! A counting `#[global_allocator]` wraps the system allocator; the test
//! warms the engine up (arena growth, stopwatch first-use inserts, stats
//! reserves all happen here), snapshots the allocation counter, runs 20
//! more speculative rounds and asserts the counter did not move.
//!
//! This file holds exactly ONE test: the harness runs it on a single
//! thread with no concurrent allocations to blur the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

use specbatch::engine::{Engine, EngineConfig};
use specbatch::policy::{Fixed, SpeculationPolicy};
use specbatch::telemetry::flight::FlightRecorder;
use specbatch::telemetry::Telemetry;
use specbatch::testkit::stub::StubSpec;

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Deterministic ragged schedule (`s_i = (round + i) % 4`): with 8 live
/// rows every round mixes at least two distinct lengths, exercising the
/// per-row `s_slot`/`s_rows` staging and the ragged feedback lend.  The
/// engine hands `choose_ragged_into` its round-scratch buffer, so the
/// `extend` below reuses warmed capacity — the policy itself is on the
/// zero-allocation hook too.
struct RaggedSchedule {
    round: Cell<usize>,
}

impl SpeculationPolicy for RaggedSchedule {
    fn choose(&self, _live: usize, max_s: usize) -> usize {
        max_s.min(3)
    }

    fn choose_ragged_into(&self, rows: &[u8], max_s: usize, out: &mut Vec<usize>) {
        let r = self.round.get();
        self.round.set(r + 1);
        out.clear();
        out.extend((0..rows.len()).map(|i| ((r + i) % 4).min(max_s)));
    }

    fn label(&self) -> String {
        "ragged-schedule".into()
    }
}

#[test]
fn steady_state_decode_rounds_allocate_nothing() {
    let spec = StubSpec {
        batch_buckets: vec![1, 2, 4, 8, 16],
        ..StubSpec::default()
    };
    let mut engine = Engine::stub(spec, EngineConfig::default()).expect("stub engine");
    let mut policy = Fixed(4);
    let prompts: Vec<Vec<i32>> = (0..8).map(|r| vec![5 + r as i32, 9 + r as i32]).collect();
    // max_new bounds total commits well past BOTH timed phases below
    // (plain + flight-recorder) and sizes the stats reserves
    let mut st = engine.prefill_rows(&prompts, 8, true, 400).expect("prefill");

    // warmup: arenas grow to their high-water mark, the stopwatch inserts
    // its section entries, the SSM catch-up path runs once
    for _ in 0..3 {
        engine.decode_round(&mut st, &mut policy).expect("warmup round");
    }

    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    for _ in 0..20 {
        engine.decode_round(&mut st, &mut policy).expect("steady round");
    }
    let delta = ALLOC_CALLS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "steady-state decode rounds must not touch the heap \
         ({delta} allocator calls across 20 rounds)"
    );
    assert!(st.has_live(), "rows must still be mid-generation");

    // --- phase 2: the always-on flight recorder rides along for free ---
    // Attach the ring to the DISABLED handle (the `--telemetry off`
    // shape): the emitters now run to feed the ring, and steady-state
    // rounds must STILL not allocate — recording is fixed-slot atomics.
    let prefix = std::env::temp_dir()
        .join(format!("specbatch_zero_alloc_flight_{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let flight = FlightRecorder::new(64, prefix);
    engine.set_telemetry(Telemetry::disabled().with_flight(flight.clone()));
    for _ in 0..3 {
        engine.decode_round(&mut st, &mut policy).expect("flight warmup round");
    }
    let recorded_before = flight.recorded();
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    for _ in 0..20 {
        engine.decode_round(&mut st, &mut policy).expect("flight steady round");
    }
    let delta = ALLOC_CALLS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "flight-recorder decode rounds must not touch the heap \
         ({delta} allocator calls across 20 rounds)"
    );
    assert!(
        flight.recorded() >= recorded_before + 20,
        "the ring must have seen every round"
    );
    assert!(st.has_live(), "rows must still be mid-generation");

    // --- phase 3: ragged per-row rounds are on the same hook ---
    // Per-row `s` staging (`s_slot`/`s_rows`), the truncated-prefix
    // commit and the ragged feedback lend must all ride the warmed
    // arenas; the flight recorder stays attached from phase 2.
    let mut ragged = RaggedSchedule {
        round: Cell::new(0),
    };
    for _ in 0..3 {
        engine.decode_round(&mut st, &mut ragged).expect("ragged warmup round");
    }
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    for _ in 0..20 {
        engine.decode_round(&mut st, &mut ragged).expect("ragged steady round");
    }
    let delta = ALLOC_CALLS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "ragged decode rounds must not touch the heap \
         ({delta} allocator calls across 20 rounds)"
    );
    assert!(st.has_live(), "rows must still be mid-generation");
}
