//! End-to-end server/client integration over the message queues: real
//! runtime, real worker thread, real Gamma traffic — scaled down so the
//! test completes in seconds.
//!
//! Requires a `--features pjrt` build and `make artifacts` (skipped
//! otherwise, loudly).  The artifact-free equivalents run on the stub
//! backend in `tests/batcher_stub.rs`.
#![cfg(feature = "pjrt")]

use std::time::Duration;

use specbatch::config::PolicySpec;
use specbatch::dataset::Dataset;
use specbatch::scheduler::Lut;
use specbatch::server::{run_experiment, Backend, SchedulingMode, ServerConfig};
use specbatch::traffic::{Trace, TrafficPattern};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts — run `make artifacts` first");
        None
    }
}

fn small_cfg() -> ServerConfig {
    ServerConfig {
        max_batch: 4,
        max_new_tokens: 8,
        ..ServerConfig::default()
    }
}

#[test]
fn serves_a_trace_and_accounts_every_request() {
    let Some(dir) = artifacts_dir() else { return };
    let dataset = Dataset::load(dir.join("dataset.json")).expect("dataset");
    let trace = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.05,
            cv: 1.0,
        },
        &dataset.eval,
        10,
        3,
    );
    let out = run_experiment(
        Backend::Artifacts(dir),
        small_cfg(),
        PolicySpec::Fixed(2),
        None,
        &trace,
    )
    .expect("experiment");
    assert!(out.lut.is_none());
    let rec = &out.recorder;
    assert_eq!(rec.len(), 10);
    // every id served exactly once
    let mut ids: Vec<u64> = rec.records().iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    for r in rec.records() {
        // causality and the paper's latency definition t_b - t_a
        assert!(r.started_at >= r.sent_at - 1e-6, "start before send");
        assert!(r.finished_at > r.started_at, "finish before start");
        assert!(r.latency() >= r.service_time() - 1e-9);
        assert_eq!(r.tokens, 8);
        assert!(r.batch >= 1 && r.batch <= 4);
        assert_eq!(r.spec_len, 2);
    }
}

#[test]
fn burst_traffic_gets_batched() {
    let Some(dir) = artifacts_dir() else { return };
    let dataset = Dataset::load(dir.join("dataset.json")).expect("dataset");
    // near-simultaneous arrivals: after the first batch, the rest must
    // merge (batch > 1 for some requests)
    let trace = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.001,
            cv: 0.5,
        },
        &dataset.eval,
        8,
        5,
    );
    let out = run_experiment(
        Backend::Artifacts(dir),
        small_cfg(),
        PolicySpec::Fixed(1),
        None,
        &trace,
    )
    .expect("experiment");
    let rec = &out.recorder;
    assert_eq!(rec.len(), 8);
    let max_batch = rec.records().iter().map(|r| r.batch).max().unwrap();
    assert!(max_batch > 1, "burst should produce merged batches");
    assert!(max_batch <= 4, "batch cap violated");
}

#[test]
fn adaptive_policy_profiles_then_serves() {
    let Some(dir) = artifacts_dir() else { return };
    let dataset = Dataset::load(dir.join("dataset.json")).expect("dataset");
    let trace = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.05,
            cv: 1.0,
        },
        &dataset.eval,
        4,
        7,
    );
    let mut cfg = small_cfg();
    cfg.profile_prompts = 4; // keep profiling quick
    let out = run_experiment(
        Backend::Artifacts(dir),
        cfg,
        PolicySpec::Adaptive,
        None,
        &trace,
    )
    .expect("experiment");
    assert_eq!(out.recorder.len(), 4);
    let lut = out.lut.expect("adaptive must yield a LUT");
    for (&b, &s) in lut.entries() {
        assert!(b >= 1);
        assert!(s <= 8, "absurd speculation length {s} for bucket {b}");
    }
}

#[test]
fn precomputed_lut_skips_profiling() {
    let Some(dir) = artifacts_dir() else { return };
    let dataset = Dataset::load(dir.join("dataset.json")).expect("dataset");
    let trace = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.02,
            cv: 1.0,
        },
        &dataset.eval,
        4,
        9,
    );
    let lut = Lut::new([(1, 3), (2, 2), (4, 2)].into_iter().collect()).unwrap();
    let t0 = std::time::Instant::now();
    let out = run_experiment(
        Backend::Artifacts(dir),
        small_cfg(),
        PolicySpec::Adaptive,
        Some(lut.clone()),
        &trace,
    )
    .expect("experiment");
    assert_eq!(out.recorder.len(), 4);
    assert_eq!(out.lut, Some(lut));
    // generous bound: no profiling pass means startup stays modest
    assert!(t0.elapsed() < Duration::from_secs(300));
}

#[test]
fn continuous_mode_serves_a_trace_on_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let dataset = Dataset::load(dir.join("dataset.json")).expect("dataset");
    let trace = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.02,
            cv: 1.0,
        },
        &dataset.eval,
        8,
        13,
    );
    let mut cfg = small_cfg();
    cfg.mode = SchedulingMode::Continuous;
    let out = run_experiment(
        Backend::Artifacts(dir),
        cfg,
        PolicySpec::Fixed(2),
        None,
        &trace,
    )
    .expect("experiment");
    let (rec, rounds) = (&out.recorder, &out.timeline);
    assert_eq!(rec.len(), 8);
    assert!(!rounds.is_empty(), "continuous mode must record rounds");
    for r in rec.records() {
        assert!(r.started_at >= r.sent_at - 1e-6);
        assert!(r.finished_at > r.started_at);
        assert_eq!(r.tokens, 8);
    }
}
