//! Pinned-seed equivalence for the zero-allocation hot path.
//!
//! The SoA `BatchState`, round-scratch arenas, flat block tables and
//! batched PRNG draws are pure layout/allocation changes: every output
//! must stay **byte-identical** to the pre-refactor code.  Three anchors
//! pin that:
//!
//! * hard-coded goldens computed by an independent Python mirror of the
//!   stub chain (`t_{k+1} = 4 + splitmix64(t_k ^ 0x5eed11) % (vocab-4)`),
//!   plus an in-test Rust re-implementation of the same chain — the
//!   engine, the continuous batcher and the threaded stub server must
//!   all reproduce it exactly (speculation is lossless, so the reference
//!   is policy- and batching-independent);
//! * acceptance sampling through a bulk-filled [`DrawBuffer`] must
//!   consume the *same* draws as sequential sampling and, after
//!   [`DrawBuffer::refund`], leave the generator in the *same* state;
//! * the DES and cluster-DES replay bit-identically across reruns at
//!   every pinned seed.

use std::cell::Cell;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use specbatch::batcher::{BatchRequest, BatcherConfig, ContinuousBatcher};
use specbatch::cluster::build_router;
use specbatch::cluster::sim::simulate_trace_cluster;
use specbatch::config::{PolicySpec, RouterSpec};
use specbatch::engine::{Engine, EngineConfig};
use specbatch::kvcache::KvLayout;
use specbatch::policy::{Fixed, NoSpec, SpeculationPolicy};
use specbatch::scheduler::Lut;
use specbatch::server::{spawn_server, Backend, SchedulingMode, ServerMsg, ServerRequest};
use specbatch::simulator::{simulate_trace_continuous, AcceptanceProcess};
use specbatch::testkit::harness::{
    const_prompt_pool, paper_sim_config, stationary_trace, stub_server_cfg,
};
use specbatch::testkit::stub::StubSpec;
use specbatch::util::prng::{DrawBuffer, Pcg64};

const SEEDS: [u64; 3] = [2, 3, 4];

// ------------------------------------------------------- reference chain

/// Independent re-implementation of the stub LLM chain (kept deliberately
/// separate from `testkit::stub` so a regression there cannot hide here).
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

fn chain_ref(last_prompt_token: i32, n_new: usize, vocab: usize) -> Vec<i32> {
    let mut t = last_prompt_token;
    (0..n_new)
        .map(|_| {
            t = 4 + (splitmix64(t as u64 ^ 0x5eed_11) % (vocab as u64 - 4)) as i32;
            t
        })
        .collect()
}

/// Deterministic 4-row prompt set per seed (lengths 1..=3, ids in
/// `[4, 64)`) — the same arithmetic the Python golden generator used.
fn prompts_for(seed: u64) -> Vec<Vec<i32>> {
    (0..4usize)
        .map(|r| {
            let plen = 1 + ((seed as usize + r) % 3);
            (0..plen)
                .map(|k| 4 + ((seed as usize * 7 + r * 13 + k * 29) % 60) as i32)
                .collect()
        })
        .collect()
}

// ------------------------------------------------- static engine goldens

/// Hard-coded continuations computed by the Python mirror (12 new tokens,
/// vocab 64) for `prompts_for(2|3|4)`.
fn python_goldens(seed: u64) -> Vec<Vec<i32>> {
    match seed {
        2 => vec![
            vec![7, 62, 45, 21, 27, 32, 24, 44, 5, 42, 33, 37],
            vec![45, 21, 27, 32, 24, 44, 5, 42, 33, 37, 60, 61],
            vec![27, 32, 24, 44, 5, 42, 33, 37, 60, 61, 35, 7],
            vec![10, 23, 25, 39, 22, 59, 17, 60, 61, 35, 7, 62],
        ],
        3 => vec![
            vec![39, 22, 59, 17, 60, 61, 35, 7, 62, 45, 21, 27],
            vec![62, 45, 21, 27, 32, 24, 44, 5, 42, 33, 37, 60],
            vec![45, 21, 27, 32, 24, 44, 5, 42, 33, 37, 60, 61],
            vec![47, 16, 7, 62, 45, 21, 27, 32, 24, 44, 5, 42],
        ],
        4 => vec![
            vec![35, 7, 62, 45, 21, 27, 32, 24, 44, 5, 42, 33],
            vec![15, 56, 28, 32, 24, 44, 5, 42, 33, 37, 60, 61],
            vec![63, 54, 33, 37, 60, 61, 35, 7, 62, 45, 21, 27],
            vec![23, 25, 39, 22, 59, 17, 60, 61, 35, 7, 62, 45],
        ],
        _ => unreachable!("unpinned seed"),
    }
}

fn stub_engine() -> Engine<'static> {
    Engine::stub(
        StubSpec::default(),
        EngineConfig {
            stop_at_eos: false,
            ..EngineConfig::default()
        },
    )
    .unwrap()
}

#[test]
fn static_engine_matches_the_python_goldens_at_every_pinned_seed() {
    for seed in SEEDS {
        let prompts = prompts_for(seed);
        let goldens = python_goldens(seed);
        // the chain mirror and the Python mirror must agree first
        for (p, g) in prompts.iter().zip(&goldens) {
            assert_eq!(&chain_ref(*p.last().unwrap(), 12, 64), g, "seed {seed}");
        }
        // lossless speculation: every policy reproduces the goldens
        let policies: Vec<Box<dyn SpeculationPolicy>> =
            vec![Box::new(NoSpec), Box::new(Fixed(1)), Box::new(Fixed(3))];
        for mut policy in policies {
            let mut e = stub_engine();
            let out = e.generate_batch(&prompts, 12, policy.as_mut()).unwrap();
            for (i, g) in goldens.iter().enumerate() {
                assert_eq!(
                    &out.tokens[i],
                    g,
                    "seed {seed}: policy {} diverged on row {i}",
                    policy.label()
                );
            }
        }
    }
}

// ------------------------------------------------- continuous batcher

/// Drive the continuous batcher over a seeded arrival schedule and
/// return every finished request's `(id, tokens)`, sorted by id.
fn run_batcher(seed: u64, layout: KvLayout) -> Vec<(u64, Vec<i32>)> {
    run_batcher_with(seed, layout, &mut Fixed(3))
}

fn run_batcher_with(
    seed: u64,
    layout: KvLayout,
    policy: &mut dyn SpeculationPolicy,
) -> Vec<(u64, Vec<i32>)> {
    let mut e = Engine::stub(
        StubSpec::default(),
        EngineConfig {
            kv_layout: layout,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let mut batcher = ContinuousBatcher::new(BatcherConfig {
        max_batch: 3,
        max_new_tokens: 10,
    });
    // staggered arrivals force admissions, retirement and reshapes
    let mut pending: Vec<(usize, u64, Vec<i32>)> = prompts_for(seed)
        .into_iter()
        .chain(prompts_for(seed + 7))
        .enumerate()
        .map(|(i, p)| (2 * i, i as u64, p))
        .collect();
    let mut finished = Vec::new();
    let mut step = 0usize;
    while batcher.has_work() || !pending.is_empty() {
        pending.retain(|(at, id, prompt)| {
            if *at <= step {
                batcher.enqueue(BatchRequest::new(*id, prompt.clone(), *at as f64 * 1e-3));
                false
            } else {
                true
            }
        });
        for f in batcher.step(&mut e, policy, step as f64 * 1e-3).unwrap() {
            finished.push((f.id, f.tokens));
        }
        step += 1;
        assert!(step < 10_000, "batcher failed to drain");
    }
    finished.sort_by_key(|(id, _)| *id);
    finished
}

#[test]
fn continuous_batcher_outputs_follow_the_reference_chain() {
    for seed in SEEDS {
        for layout in [KvLayout::Dense, KvLayout::Paged] {
            let finished = run_batcher(seed, layout);
            assert_eq!(finished.len(), 8, "seed {seed}");
            let expected: Vec<Vec<i32>> = prompts_for(seed)
                .into_iter()
                .chain(prompts_for(seed + 7))
                .map(|p| chain_ref(*p.last().unwrap(), 10, 64))
                .collect();
            for (i, (id, tokens)) in finished.iter().enumerate() {
                assert_eq!(*id, i as u64);
                assert_eq!(
                    tokens, &expected[i],
                    "seed {seed} {layout:?}: row {i} left the chain"
                );
            }
            // and the whole run replays byte-identically
            assert_eq!(finished, run_batcher(seed, layout), "seed {seed} rerun");
        }
    }
}

// --------------------------------------------------- threaded stub e2e

#[test]
fn threaded_stub_server_outputs_follow_the_reference_chain() {
    for seed in SEEDS {
        let cfg = stub_server_cfg(SchedulingMode::Continuous, KvLayout::default_layout());
        let max_new = cfg.max_new_tokens;
        let handle = spawn_server(
            Backend::Stub(StubSpec::default()),
            cfg,
            PolicySpec::Fixed(2),
            None,
            Instant::now(),
        );
        handle.wait_ready(Duration::from_secs(30)).expect("ready");
        let prompts = prompts_for(seed);
        for (i, p) in prompts.iter().enumerate() {
            handle
                .requests
                .send(ServerMsg::Request(ServerRequest {
                    route_hop: 0.0,
                    id: i as u64,
                    prompt: p.clone(),
                    sent_at: 0.0,
                    deadline: None,
                    class: 0,
                }))
                .expect("send");
        }
        let mut got = 0usize;
        while got < prompts.len() {
            let resp = handle
                .responses
                .recv_timeout(Duration::from_secs(30))
                .expect("response");
            assert!(!resp.shed, "seed {seed}: FIFO never sheds");
            let expected = chain_ref(
                *prompts[resp.id as usize].last().unwrap(),
                max_new,
                64,
            );
            assert_eq!(
                resp.tokens, expected,
                "seed {seed}: request {} left the chain",
                resp.id
            );
            got += 1;
        }
        handle.shutdown().expect("shutdown");
    }
}

// ------------------------------------------- DES draw-buffer equivalence

#[test]
fn acceptance_sampling_via_draw_buffer_is_bit_identical_to_sequential() {
    let p = AcceptanceProcess::paper();
    for seed in SEEDS {
        let mut seq = Pcg64::new(seed);
        let mut bulk = Pcg64::new(seed);
        let mut draws = DrawBuffer::new();
        let mut a_seq = Vec::new();
        let mut a_bulk = Vec::new();
        // varying (live, s) shapes, like successive DES rounds
        for round in 0..64usize {
            let s = 1 + round % 6;
            let live = 1 + round % 8;
            for _ in 0..live {
                a_seq.push(p.sample(s, &mut seq));
            }
            draws.ensure(&mut bulk, live * s);
            for _ in 0..live {
                a_bulk.push(p.sample(s, &mut draws));
            }
        }
        draws.refund(&mut bulk);
        assert_eq!(a_seq, a_bulk, "seed {seed}: accepted counts diverged");
        // refund must land the generator on the sequential state exactly
        assert_eq!(
            seq.next_u64(),
            bulk.next_u64(),
            "seed {seed}: post-refund stream diverged"
        );
    }
}

// ----------------------------------------------------- DES determinism

#[test]
fn des_and_cluster_des_replay_bit_identically_at_every_pinned_seed() {
    for seed in SEEDS {
        let cfg = paper_sim_config(seed);
        let trace = stationary_trace(&const_prompt_pool(12), 60, seed, 0.05, 1.0);

        let des = |cfg, trace| {
            let mut policy = Fixed(3);
            let (rec, rounds) = simulate_trace_continuous(cfg, &mut policy, trace);
            let recs: Vec<(u64, f64, f64, usize)> = rec
                .records()
                .iter()
                .map(|r| (r.id, r.started_at, r.finished_at, r.batch))
                .collect();
            let rds: Vec<(f64, usize, usize, usize)> =
                rounds.iter().map(|e| (e.t, e.live, e.s, e.accepted)).collect();
            (recs, rds)
        };
        assert_eq!(des(&cfg, &trace), des(&cfg, &trace), "seed {seed}: DES rerun");

        let cluster = |cfg: &_, trace: &_| {
            let mut policies: Vec<Box<dyn SpeculationPolicy>> =
                (0..3).map(|_| Box::new(Fixed(2)) as Box<dyn SpeculationPolicy>).collect();
            let mut router = build_router(RouterSpec::JoinShortestQueue, 0);
            let report = simulate_trace_cluster(cfg, &mut policies, router.as_mut(), trace);
            let mut recs: Vec<(u64, usize, f64, f64)> = report
                .recorder
                .records()
                .iter()
                .map(|r| (r.id, r.shard, r.started_at, r.finished_at))
                .collect();
            recs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            recs
        };
        assert_eq!(
            cluster(&cfg, &trace),
            cluster(&cfg, &trace),
            "seed {seed}: cluster rerun"
        );
    }
}

// ------------------------------------------- ragged-round equivalence

/// Deterministic per-row, per-round speculation schedule: row `i` of
/// round `r` drafts `(r + i) % 4` tokens (capped at `max_s`).  Adjacent
/// rows differ, so every round with two or more live rows is genuinely
/// ragged, and the schedule depends only on `(round, row)` — never on
/// sampled outcomes — so reruns see the same per-row `s` vectors.
struct RaggedSchedule {
    round: Cell<usize>,
}

impl RaggedSchedule {
    fn new() -> Self {
        RaggedSchedule {
            round: Cell::new(0),
        }
    }
}

impl SpeculationPolicy for RaggedSchedule {
    fn choose(&self, _live: usize, max_s: usize) -> usize {
        max_s.min(3)
    }

    fn choose_ragged_into(&self, rows: &[u8], max_s: usize, out: &mut Vec<usize>) {
        let r = self.round.get();
        self.round.set(r + 1);
        out.clear();
        out.extend((0..rows.len()).map(|i| ((r + i) % 4).min(max_s)));
    }

    fn label(&self) -> String {
        "ragged-schedule".into()
    }
}

/// Speculation is lossless row by row, so a ragged per-row schedule must
/// leave every output **bit-identical** to the uniform policies and the
/// Python goldens — through the static engine AND the continuous
/// batcher (where admissions and retirement reshuffle rows mid-flight).
#[test]
fn ragged_schedules_keep_every_output_on_the_reference_chain() {
    for seed in SEEDS {
        // static engine: ragged == goldens == uniform
        let prompts = prompts_for(seed);
        let mut policy = RaggedSchedule::new();
        let mut e = stub_engine();
        let out = e.generate_batch(&prompts, 12, &mut policy).unwrap();
        assert_eq!(out.tokens, python_goldens(seed), "seed {seed}: engine");

        // continuous batcher: ragged matches the uniform Fixed(3) run
        // token for token, id for id, on both KV layouts
        for layout in [KvLayout::Dense, KvLayout::Paged] {
            let ragged = run_batcher_with(seed, layout, &mut RaggedSchedule::new());
            assert_eq!(
                ragged,
                run_batcher(seed, layout),
                "seed {seed} {layout:?}: ragged batcher left the chain"
            );
            // and the ragged run itself replays byte-identically
            assert_eq!(
                ragged,
                run_batcher_with(seed, layout, &mut RaggedSchedule::new()),
                "seed {seed} {layout:?}: ragged rerun"
            );
        }
    }
}

/// Class-tagged requests through the threaded stub server with the
/// online model-based policy: the `class` field must ride the
/// request → batcher → engine-slot plumbing without perturbing outputs
/// (whatever per-row lengths the policy picks, tokens stay on chain).
#[test]
fn threaded_server_with_class_tagged_requests_stays_on_the_chain() {
    for seed in SEEDS {
        let cfg = stub_server_cfg(SchedulingMode::Continuous, KvLayout::default_layout());
        let max_new = cfg.max_new_tokens;
        let lut = Lut::new(BTreeMap::from([(1, 4), (2, 3), (4, 2)])).unwrap();
        let handle = spawn_server(
            Backend::Stub(StubSpec::default()),
            cfg,
            PolicySpec::ModelBased,
            Some(lut),
            Instant::now(),
        );
        handle.wait_ready(Duration::from_secs(30)).expect("ready");
        let prompts = prompts_for(seed);
        for (i, p) in prompts.iter().enumerate() {
            handle
                .requests
                .send(ServerMsg::Request(ServerRequest {
                    route_hop: 0.0,
                    id: i as u64,
                    prompt: p.clone(),
                    sent_at: 0.0,
                    deadline: None,
                    // two classes interleaved inside one batch
                    class: (i % 2) as u8,
                }))
                .expect("send");
        }
        let mut got = 0usize;
        while got < prompts.len() {
            let resp = handle
                .responses
                .recv_timeout(Duration::from_secs(30))
                .expect("response");
            assert!(!resp.shed, "seed {seed}: FIFO never sheds");
            let expected = chain_ref(*prompts[resp.id as usize].last().unwrap(), max_new, 64);
            assert_eq!(
                resp.tokens, expected,
                "seed {seed}: class-tagged request {} left the chain",
                resp.id
            );
            got += 1;
        }
        handle.shutdown().expect("shutdown");
    }
}

/// The DES under the same deterministic ragged schedule: rounds must
/// actually be ragged (`drafted < live * s_max`) and the whole run —
/// per-request records and the round timeline — must replay
/// bit-identically.
#[test]
fn des_replays_bit_identically_under_a_ragged_schedule() {
    for seed in SEEDS {
        let cfg = paper_sim_config(seed);
        let trace = stationary_trace(&const_prompt_pool(12), 60, seed, 0.05, 1.0)
            .with_classes_alternating(2);

        let run = || {
            let mut policy = RaggedSchedule::new();
            simulate_trace_continuous(&cfg, &mut policy, &trace)
        };
        let (rec_a, rounds_a) = run();
        let (rec_b, rounds_b) = run();
        assert_eq!(rec_a.records(), rec_b.records(), "seed {seed}: records rerun");
        assert_eq!(rounds_a, rounds_b, "seed {seed}: rounds rerun");

        let ragged = rounds_a
            .iter()
            .filter(|r| r.s > 0 && r.drafted < r.live * r.s)
            .count();
        assert!(
            ragged > 0,
            "seed {seed}: schedule never produced a ragged round"
        );
        for r in rounds_a.iter().filter(|r| r.s > 0) {
            assert!(r.drafted <= r.live * r.s, "seed {seed}: rectangle violated");
        }
    }
}
