//! The cluster-routing acceptance test (the tentpole payoff): on a
//! seeded bursty trace served by N = 4 worker shards, speculation-aware
//! routing wins — `CostAware` <= `PowerOfTwo` <= `RoundRobin` in mean
//! per-token latency, deterministically across three seeds — and the
//! per-shard chosen speculation lengths diverge whenever shard loads
//! diverge, demonstrating the paper's batch-dependent `s_opt` at cluster
//! scale.
//!
//! Scenario: the Fig. 6 alternating intense/sparse pattern, time-scaled
//! to cluster load (4 workers absorb ~4x a single worker's traffic), with
//! every shard running its own online [`ModelBased`] policy.  The
//! cost-aware router reads each shard's fitted batch↔s_opt curve and
//! places arrivals where the predicted marginal per-token latency
//! increase is smallest; power-of-two corrects imbalance with two random
//! probes; round-robin ignores shard state entirely and lets transient
//! imbalance (burst onsets, retirement waves) queue behind busy shards.

use specbatch::cluster::sim::{simulate_trace_cluster, ClusterReport};
use specbatch::cluster::{build_router, replicate_policies};
use specbatch::config::{PolicySpec, RouterSpec};
use specbatch::simulator::{simulated_lut, SimConfig};
use specbatch::testkit::harness::{const_prompt_pool, fig6_trace, paper_sim_config};
use specbatch::traffic::Trace;

const WORKERS: usize = 4;
const N_REQUESTS: usize = 800;
/// Fig. 6 send times compressed 1/0.15 ≈ 6.7x: four shards at
/// moderate-heavy load, where placement decides queueing.
const TIME_SCALE: f64 = 0.15;
const SEEDS: [u64; 3] = [5, 12, 14];

fn cfg(seed: u64) -> SimConfig {
    paper_sim_config(seed)
}

fn bursty_trace(seed: u64) -> Trace {
    fig6_trace(&const_prompt_pool(16), N_REQUESTS, seed, TIME_SCALE)
}

fn run(router: RouterSpec, seed: u64) -> ClusterReport {
    let cfg = cfg(seed);
    let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
    let trace = bursty_trace(seed);
    let mut policies =
        replicate_policies(&PolicySpec::ModelBased, Some(&lut), WORKERS).unwrap();
    let mut r = build_router(router, seed);
    let report = simulate_trace_cluster(&cfg, &mut policies, r.as_mut(), &trace);
    assert_eq!(report.recorder.len(), N_REQUESTS, "request conservation");
    report
}

#[test]
fn cost_aware_beats_power_of_two_beats_round_robin_across_seeds() {
    let mut means = (0.0, 0.0, 0.0);
    for seed in SEEDS {
        let ca = run(RouterSpec::CostAware, seed)
            .recorder
            .mean_per_token_latency();
        let p2 = run(RouterSpec::PowerOfTwo, seed)
            .recorder
            .mean_per_token_latency();
        let rr = run(RouterSpec::RoundRobin, seed)
            .recorder
            .mean_per_token_latency();
        assert!(
            ca <= p2,
            "seed {seed}: cost-aware ({:.3} ms/tok) must not lose to \
             power-of-two ({:.3} ms/tok)",
            ca * 1e3,
            p2 * 1e3
        );
        assert!(
            p2 <= rr,
            "seed {seed}: power-of-two ({:.3} ms/tok) must not lose to \
             round-robin ({:.3} ms/tok)",
            p2 * 1e3,
            rr * 1e3
        );
        means.0 += ca;
        means.1 += p2;
        means.2 += rr;
    }
    // averaged over the seeds the ordering is strict with real margin
    assert!(
        means.0 * 1.005 < means.1,
        "cost-aware must beat power-of-two on average: {:.4} vs {:.4} ms/tok",
        means.0 / 3.0 * 1e3,
        means.1 / 3.0 * 1e3
    );
    assert!(
        means.1 * 1.005 < means.2,
        "power-of-two must beat round-robin on average: {:.4} vs {:.4} ms/tok",
        means.1 / 3.0 * 1e3,
        means.2 / 3.0 * 1e3
    );
}

#[test]
fn cluster_runs_are_deterministic_per_seed() {
    for router in [RouterSpec::CostAware, RouterSpec::PowerOfTwo] {
        let a = run(router, SEEDS[0]);
        let b = run(router, SEEDS[0]);
        let key = |r: &ClusterReport| {
            let mut v: Vec<(u64, usize, f64)> = r
                .recorder
                .records()
                .iter()
                .map(|x| (x.id, x.shard, x.finished_at))
                .collect();
            v.sort_by(|x, y| x.0.cmp(&y.0));
            v
        };
        assert_eq!(
            key(&a),
            key(&b),
            "{} replays must be bit-identical",
            a.router
        );
    }
}

/// The synergy witness: each shard's chosen `s` tracks its OWN live
/// batch, so when the router lets loads diverge, speculation lengths
/// diverge with them — lightly loaded shards speculate long, heavily
/// loaded shards speculate short, concurrently in the same cluster.
#[test]
fn per_shard_chosen_s_diverges_when_shard_loads_diverge() {
    for seed in SEEDS {
        let report = run(RouterSpec::CostAware, seed);

        // within every shard: small-batch rounds speculate much longer
        for (k, rounds) in report.shard_rounds.iter().enumerate() {
            let cell = |lo: usize, hi: usize| -> (f64, usize) {
                let xs: Vec<f64> = rounds
                    .iter()
                    .filter(|e| e.live >= lo && e.live <= hi)
                    .map(|e| e.s as f64)
                    .collect();
                let n = xs.len();
                (xs.iter().sum::<f64>() / n.max(1) as f64, n)
            };
            let (s_small, n_small) = cell(1, 2);
            let (s_large, n_large) = cell(8, usize::MAX);
            assert!(
                n_small >= 20 && n_large >= 20,
                "seed {seed} shard {k}: too few rounds to judge \
                 ({n_small} small, {n_large} large)"
            );
            assert!(
                s_small >= s_large + 2.0,
                "seed {seed} shard {k}: s must shrink with the live batch \
                 (mean s {s_small:.2} at live<=2 vs {s_large:.2} at live>=8)"
            );
        }

        // across shards at the same instant: when loads diverge by a
        // bucket or more, the lighter shard speculates at least as long,
        // and strictly longer on a large share of those moments
        let mut pairs = 0usize;
        let mut lighter_ge = 0usize;
        let mut strict = 0usize;
        for i in 0..report.shard_rounds.len() {
            for j in (i + 1)..report.shard_rounds.len() {
                for a in report.shard_rounds[i].iter().step_by(3) {
                    let (a_lo, a_hi) = (a.t - a.round_cost, a.t);
                    for b in &report.shard_rounds[j] {
                        if b.t - b.round_cost > a_hi {
                            break;
                        }
                        if b.t < a_lo {
                            continue;
                        }
                        if a.live.abs_diff(b.live) < 4 {
                            continue;
                        }
                        pairs += 1;
                        let (light, heavy) =
                            if a.live < b.live { (a, b) } else { (b, a) };
                        if light.s >= heavy.s {
                            lighter_ge += 1;
                        }
                        if light.s > heavy.s {
                            strict += 1;
                        }
                    }
                }
            }
        }
        assert!(
            pairs >= 50,
            "seed {seed}: loads never diverged concurrently ({pairs} pairs)"
        );
        assert!(
            lighter_ge * 10 >= pairs * 7,
            "seed {seed}: lighter shard should speculate >= heavier in >=70% \
             of divergent moments ({lighter_ge}/{pairs})"
        );
        assert!(
            strict * 10 >= pairs * 4,
            "seed {seed}: strict s divergence expected in >=40% of divergent \
             moments ({strict}/{pairs})"
        );
    }
}
