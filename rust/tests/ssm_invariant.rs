//! Pins the KV state-machine invariant DESIGN.md states but nothing
//! previously tested across admissions and epoch reshapes: after every
//! **speculative** round, each slot satisfies
//! `ingested == committed.len() - 1` for BOTH models (the last committed
//! token is fed, never pre-ingested), and between speculative rounds the
//! SSM's backlog never overtakes the LLM.  Runs on the stub backend, so
//! it exercises the identical counter logic the PJRT path uses.

use specbatch::engine::{AdmitRequest, BatchState, Engine, EngineConfig};
use specbatch::policy::{Fixed, NoSpec};
use specbatch::testkit::stub::StubSpec;

fn stub_engine() -> Engine<'static> {
    Engine::stub(StubSpec::default(), EngineConfig::default()).unwrap()
}

/// Both models sit exactly one token behind the committed stream.
fn assert_caught_up(st: &BatchState, when: &str) {
    for (slot, (committed, llm_ing, ssm_ing)) in st.ingest_state().into_iter().enumerate() {
        assert_eq!(
            llm_ing as usize,
            committed - 1,
            "{when}: LLM ingest invariant broken on slot {slot}"
        );
        let ssm_ing = ssm_ing.expect("speculating epoch owns an SSM KV");
        assert_eq!(
            ssm_ing as usize,
            committed - 1,
            "{when}: SSM ingest invariant broken on slot {slot}"
        );
    }
}

/// The SSM may lag (catch-up backlog) but never lead the LLM.
fn assert_ssm_never_leads(st: &BatchState, when: &str) {
    for (slot, (committed, llm_ing, ssm_ing)) in st.ingest_state().into_iter().enumerate() {
        assert!(
            (llm_ing as usize) <= committed - 1,
            "{when}: LLM ingested past committed-1 on slot {slot}"
        );
        if let Some(ssm_ing) = ssm_ing {
            assert!(
                ssm_ing <= llm_ing,
                "{when}: SSM ({ssm_ing}) ahead of LLM ({llm_ing}) on slot {slot}"
            );
        }
    }
}

#[test]
fn delta_invariant_holds_through_admissions() {
    let mut e = stub_engine();
    let mut policy = Fixed(2);
    let mut st = e.prefill_rows(&[vec![5, 9], vec![7]], 4, true, 24).unwrap();

    // speculative rounds keep both models exactly one behind
    for _ in 0..3 {
        e.decode_round(&mut st, &mut policy).unwrap();
        assert_caught_up(&st, "after speculative round");
    }

    // a plain round (s = 0) opens an SSM backlog...
    e.decode_round(&mut st, &mut NoSpec).unwrap();
    assert_ssm_never_leads(&st, "after plain round");

    // ...and admission mid-epoch opens one for the fresh rows too
    let slots = e
        .admit_rows(
            &mut st,
            &[AdmitRequest {
                context: vec![30, 31, 32],
                prompt_len: 3,
                max_new: 24,
            }],
        )
        .unwrap();
    assert_eq!(slots.len(), 1);
    assert_ssm_never_leads(&st, "after admission");

    // the catch-up pass before the next speculative round restores the
    // delta invariant for every slot, admitted rows included
    e.decode_round(&mut st, &mut policy).unwrap();
    assert_caught_up(&st, "after catch-up + speculative round");
}

#[test]
fn delta_invariant_holds_across_an_epoch_reshape() {
    let mut e = stub_engine();
    let mut policy = Fixed(3);

    // epoch 1 at bucket 2: generate a few tokens
    let mut st = e.prefill_rows(&[vec![5, 9], vec![7, 8]], 2, true, 30).unwrap();
    for _ in 0..4 {
        e.decode_round(&mut st, &mut policy).unwrap();
    }
    assert_caught_up(&st, "epoch 1 steady state");

    // reshape: carry the unfinished rows into a larger bucket, exactly as
    // the continuous batcher does (prefill fresh rows, re-admit carried)
    let carried: Vec<AdmitRequest> =
        e.export_rows(&st).into_iter().map(|(_, req)| req).collect();
    assert_eq!(carried.len(), 2, "both rows still mid-generation");
    let mut st2 = e.prefill_rows(&[vec![40, 41]], 4, true, 30).unwrap();
    let slots = e.admit_rows(&mut st2, &carried).unwrap();
    assert_eq!(slots.len(), 2);

    // carried contexts are longer than the SSM has seen: backlog, not lead
    assert_ssm_never_leads(&st2, "after reshape admission");

    // first speculative round of the reshaped epoch drains the backlog
    e.decode_round(&mut st2, &mut policy).unwrap();
    assert_caught_up(&st2, "after reshape catch-up round");

    // and the reshaped epoch still finishes every row losslessly
    while st2.has_live() {
        e.decode_round(&mut st2, &mut policy).unwrap();
        assert_caught_up(&st2, "reshaped epoch rounds");
    }
    let retired = e.retire_finished(&mut st2);
    assert_eq!(retired.len(), 3);
    for r in &retired {
        assert_eq!(r.tokens.len(), 30, "slot {} truncated", r.slot);
    }
}
