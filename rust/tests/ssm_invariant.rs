//! Pins the KV state-machine invariant DESIGN.md states but nothing
//! previously tested across admissions and epoch reshapes: after every
//! **speculative** round, each slot satisfies
//! `llm_ingested == committed.len() - 1` (the last committed token is
//! fed, never pre-ingested) and the SSM sits a **delta of 1..=2** behind
//! the committed stream — 1 after a partial acceptance, 2 after a fully
//! accepted round (the stub's speculate advances counters by
//! `dlen + s - 1`, so a full acceptance leaves the last draft and the
//! bonus token un-ingested; that is exactly the window `build_delta`
//! handles without a catch-up pass).  Between speculative rounds the
//! SSM's backlog may grow but never overtakes the LLM.  Runs on the stub
//! backend, so it exercises the identical counter logic the PJRT path
//! uses — under both the chunked-reingest (dense) and block-table-remap
//! (paged) reshape paths.

use specbatch::engine::{AdmitRequest, BatchState, Engine, EngineConfig};
use specbatch::kvcache::KvLayout;
use specbatch::policy::{Fixed, NoSpec};
use specbatch::testkit::stub::StubSpec;

fn stub_engine() -> Engine<'static> {
    Engine::stub(StubSpec::default(), EngineConfig::default()).unwrap()
}

fn paged_engine() -> Engine<'static> {
    Engine::stub(
        StubSpec::default(),
        EngineConfig {
            kv_layout: KvLayout::Paged,
            ..EngineConfig::default()
        },
    )
    .unwrap()
}

/// The steady state after a speculative round: the LLM sits exactly one
/// token behind the committed stream; the SSM sits within the 1..=2
/// delta window and never ahead of the LLM.
fn assert_delta_invariant(st: &BatchState, when: &str) {
    for (slot, (committed, llm_ing, ssm_ing)) in st.ingest_state().into_iter().enumerate() {
        assert_eq!(
            llm_ing as usize,
            committed - 1,
            "{when}: LLM ingest invariant broken on slot {slot}"
        );
        let ssm_ing = ssm_ing.expect("speculating epoch owns an SSM KV") as usize;
        let missing = committed - ssm_ing;
        assert!(
            (1..=2).contains(&missing),
            "{when}: SSM delta {missing} outside 1..=2 on slot {slot} \
             (committed {committed}, ingested {ssm_ing})"
        );
        assert!(
            ssm_ing <= llm_ing as usize,
            "{when}: SSM ({ssm_ing}) ahead of LLM ({llm_ing}) on slot {slot}"
        );
    }
}

/// The SSM may lag arbitrarily (catch-up backlog) but never lead the LLM.
fn assert_ssm_never_leads(st: &BatchState, when: &str) {
    for (slot, (committed, llm_ing, ssm_ing)) in st.ingest_state().into_iter().enumerate() {
        assert!(
            (llm_ing as usize) <= committed - 1,
            "{when}: LLM ingested past committed-1 on slot {slot}"
        );
        if let Some(ssm_ing) = ssm_ing {
            assert!(
                ssm_ing <= llm_ing,
                "{when}: SSM ({ssm_ing}) ahead of LLM ({llm_ing}) on slot {slot}"
            );
        }
    }
}

#[test]
fn delta_invariant_holds_through_admissions() {
    let mut e = stub_engine();
    let mut policy = Fixed(2);
    let mut st = e.prefill_rows(&[vec![5, 9], vec![7]], 4, true, 24).unwrap();

    // speculative rounds keep every slot inside the delta window
    for _ in 0..3 {
        e.decode_round(&mut st, &mut policy).unwrap();
        assert_delta_invariant(&st, "after speculative round");
    }

    // a plain round (s = 0) opens an SSM backlog...
    e.decode_round(&mut st, &mut NoSpec).unwrap();
    assert_ssm_never_leads(&st, "after plain round");

    // ...and admission mid-epoch opens one for the fresh rows too
    let slots = e
        .admit_rows(&mut st, vec![AdmitRequest::fresh(vec![30, 31, 32], 3, 24)])
        .unwrap();
    assert_eq!(slots.len(), 1);
    assert_ssm_never_leads(&st, "after admission");

    // the catch-up pass before the next speculative round restores the
    // delta invariant for every slot, admitted rows included
    e.decode_round(&mut st, &mut policy).unwrap();
    assert_delta_invariant(&st, "after catch-up + speculative round");
}

#[test]
fn delta_invariant_holds_across_an_epoch_reshape() {
    let mut e = stub_engine();
    let mut policy = Fixed(3);

    // epoch 1 at bucket 2: generate a few tokens
    let mut st = e.prefill_rows(&[vec![5, 9], vec![7, 8]], 2, true, 30).unwrap();
    for _ in 0..4 {
        e.decode_round(&mut st, &mut policy).unwrap();
    }
    assert_delta_invariant(&st, "epoch 1 steady state");

    // reshape: carry the unfinished rows into a larger bucket, exactly as
    // the continuous batcher does (prefill fresh rows, re-admit carried)
    let mut exported = Vec::new();
    e.export_rows(&st, &mut exported);
    let carried: Vec<AdmitRequest> = exported.into_iter().map(|(_, req)| req).collect();
    assert_eq!(carried.len(), 2, "both rows still mid-generation");
    e.release_state(&mut st);
    let mut st2 = e.prefill_rows(&[vec![40, 41]], 4, true, 30).unwrap();
    let slots = e.admit_rows(&mut st2, carried).unwrap();
    assert_eq!(slots.len(), 2);

    // carried contexts are longer than the SSM has seen: backlog, not lead
    assert_ssm_never_leads(&st2, "after reshape admission");

    // first speculative round of the reshaped epoch drains the backlog
    e.decode_round(&mut st2, &mut policy).unwrap();
    assert_delta_invariant(&st2, "after reshape catch-up round");

    // and the reshaped epoch still finishes every row losslessly
    while st2.has_live() {
        e.decode_round(&mut st2, &mut policy).unwrap();
        assert_delta_invariant(&st2, "reshaped epoch rounds");
    }
    let retired = e.retire_finished(&mut st2);
    assert_eq!(retired.len(), 3);
    for r in &retired {
        assert_eq!(r.tokens.len(), 30, "slot {} truncated", r.slot);
    }
}

/// The paged layout's reshape path: carrying rows by **block-table
/// remap** (no re-ingestion at all) must uphold the same delta invariant
/// as the chunked-reingest path — and, unlike it, preserves the SSM's
/// ingest counters across the reshape, so the carried rows arrive with
/// their backlog already bounded instead of a whole context to re-feed.
#[test]
fn delta_invariant_holds_across_a_block_table_remap() {
    let mut e = paged_engine();
    let mut policy = Fixed(3);

    // epoch 1 at bucket 2: a speculative steady state, then one plain
    // round so a carried row ALSO brings an extra SSM backlog token
    let mut st = e.prefill_rows(&[vec![5, 9], vec![7, 8]], 2, true, 30).unwrap();
    for _ in 0..4 {
        e.decode_round(&mut st, &mut policy).unwrap();
        assert_delta_invariant(&st, "epoch 1 speculative rounds");
    }
    e.decode_round(&mut st, &mut NoSpec).unwrap();
    assert_ssm_never_leads(&st, "after plain round");

    // reshape by remap: export block chains, release the old epoch,
    // install the chains into a larger bucket next to a fresh prefill
    let mut exported = Vec::new();
    e.export_rows(&st, &mut exported);
    let carried: Vec<AdmitRequest> = exported.into_iter().map(|(_, req)| req).collect();
    assert_eq!(carried.len(), 2, "both rows still mid-generation");
    e.release_state(&mut st);
    let mut st2 = e.prefill_rows(&[vec![40, 41]], 4, true, 30).unwrap();
    let slots = e.admit_rows(&mut st2, carried).unwrap();
    assert_eq!(slots.len(), 2);

    // zero tokens re-ingested: the remap moved counters, not tokens
    assert_eq!(st2.stats.reingested_tokens, 0, "remap must not re-ingest");
    assert!(st2.stats.remapped_tokens > 0, "the chains carried real state");
    // carried rows keep their bounded backlog; nothing leads
    assert_ssm_never_leads(&st2, "after remap admission");

    // the first speculative round (catch-up included) restores the
    // delta invariant for every slot, remapped rows included
    e.decode_round(&mut st2, &mut policy).unwrap();
    assert_delta_invariant(&st2, "after remap catch-up round");

    // and the reshaped epoch still finishes every row losslessly
    while st2.has_live() {
        e.decode_round(&mut st2, &mut policy).unwrap();
        assert_delta_invariant(&st2, "remapped epoch rounds");
    }
    let retired = e.retire_finished(&mut st2);
    assert_eq!(retired.len(), 3);
    for r in &retired {
        assert_eq!(r.tokens.len(), 30, "slot {} truncated", r.slot);
    }
    // every block is back on the free list once both states are released
    e.release_state(&mut st2);
    e.clear_prefix_cache(); // cached prefix blocks are not leaks
    let stats = e.kv_block_stats().expect("paged engine reports stats");
    assert!(stats.is_leak_free(), "blocks leaked: {stats:?}");
}
