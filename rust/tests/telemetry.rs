//! Telemetry acceptance tests (ISSUE PR 6).
//!
//! Pins the four contracts the observability layer must keep:
//!
//! 1. **Invisibility** — running any DES entry point with a live
//!    trace-mode [`Telemetry`] handle produces bit-identical results to
//!    the plain entry point (telemetry consumes no PRNG draws and
//!    perturbs no float arithmetic).
//! 2. **Conservation** — every request in the trace gets exactly one
//!    terminal `Finish` event, and the shed flags agree with the
//!    recorder's terminal state.
//! 3. **Phase structure** — draft/verify/accept spans nest inside their
//!    round span, tile its duration exactly, and never overlap within a
//!    shard (rounds don't overlap either).
//! 4. **Export schemas** — the Chrome trace document is well-formed
//!    `trace_event` JSON, the JSONL exporter emits one line per event,
//!    Prometheus text carries typed families, and `BENCH_fig6.json`
//!    written from a stub-server run matches its `ExperimentOutcome`
//!    field for field after a parse round-trip.

use std::collections::BTreeMap;

use specbatch::admission::{replicate_controllers, SloAware};
use specbatch::cluster::sim::{
    simulate_trace_cluster_admission, simulate_trace_cluster_admission_tel,
};
use specbatch::cluster::{build_router, replicate_policies};
use specbatch::config::{AdmissionSpec, PolicySpec, RouterSpec};
use specbatch::kvcache::KvLayout;
use specbatch::policy::Fixed;
use specbatch::server::{run_experiment, Backend, SchedulingMode, ServerConfig};
use specbatch::simulator::{
    simulate_trace_admission, simulate_trace_admission_tel, simulate_trace_continuous_admission,
    simulate_trace_continuous_admission_tel,
};
use specbatch::telemetry::{bench, export, Event, EventKind, PhaseKind, Telemetry, TelemetryMode};
use specbatch::testkit::harness::{
    const_prompt_pool, fig6_trace, paper_sim_config, slo_fig6_trace, stub_prompt_pool,
    stub_server_cfg, warm_model_based,
};
use specbatch::testkit::stub::StubSpec;
use specbatch::util::json::Json;

const EPS: f64 = 1e-9;

// ---------------------------------------------------------------- invisibility

#[test]
fn trace_telemetry_is_invisible_to_the_static_des() {
    for seed in [2u64, 3, 4] {
        let mut cfg = paper_sim_config(seed);
        cfg.max_new_tokens = 32;
        let trace = slo_fig6_trace(&const_prompt_pool(12), 150, seed, 0.1, 1.5, 2.0);

        let off = simulate_trace_admission(
            &cfg,
            &mut Fixed(2),
            &mut SloAware::default(),
            &trace,
        );
        let tel = Telemetry::new(TelemetryMode::Trace);
        let on = simulate_trace_admission_tel(
            &cfg,
            &mut Fixed(2),
            &mut SloAware::default(),
            &trace,
            &tel,
        );

        assert_eq!(off.records(), on.records(), "seed {seed}: records diverged");
        assert!(
            tel.events()
                .iter()
                .any(|e| matches!(e.kind, EventKind::Round { .. })),
            "seed {seed}: trace mode must record round events"
        );
    }
}

#[test]
fn trace_telemetry_is_invisible_to_the_continuous_des() {
    for seed in [2u64, 3, 4] {
        let mut cfg = paper_sim_config(seed);
        cfg.max_new_tokens = 32;
        let trace = slo_fig6_trace(&const_prompt_pool(12), 200, seed, 0.1, 1.5, 2.0);

        // fresh policy + controller per run: both mutate while observing
        let mut p_off = warm_model_based(&cfg, 30);
        let (rec_off, rounds_off) = simulate_trace_continuous_admission(
            &cfg,
            &mut p_off,
            &mut SloAware::default(),
            &trace,
        );
        let mut p_on = warm_model_based(&cfg, 30);
        let tel = Telemetry::new(TelemetryMode::Trace);
        let (rec_on, rounds_on) = simulate_trace_continuous_admission_tel(
            &cfg,
            &mut p_on,
            &mut SloAware::default(),
            &trace,
            &tel,
        );

        assert_eq!(rec_off.records(), rec_on.records(), "seed {seed}: records");
        assert_eq!(rounds_off, rounds_on, "seed {seed}: round timeline");
    }
}

#[test]
fn trace_telemetry_is_invisible_to_the_cluster_des() {
    for seed in [2u64, 3, 4] {
        let mut cfg = paper_sim_config(seed);
        cfg.max_new_tokens = 32;
        let trace = slo_fig6_trace(&const_prompt_pool(12), 200, seed, 0.1, 1.5, 2.0);
        let workers = 3;

        let run = |tel: &Telemetry| {
            let mut policies =
                replicate_policies(&PolicySpec::Fixed(2), None, workers).expect("no LUT needed");
            let mut ctrls = replicate_controllers(AdmissionSpec::SloAware, workers);
            let mut router = build_router(RouterSpec::CostAware, seed);
            simulate_trace_cluster_admission_tel(
                &cfg,
                &mut policies,
                &mut ctrls,
                router.as_mut(),
                &trace,
                tel,
            )
        };
        // the disabled handle IS the plain entry point (it delegates), but
        // run both spellings so a future fork of the wrapper gets caught
        let off = {
            let mut policies =
                replicate_policies(&PolicySpec::Fixed(2), None, workers).expect("no LUT needed");
            let mut ctrls = replicate_controllers(AdmissionSpec::SloAware, workers);
            let mut router = build_router(RouterSpec::CostAware, seed);
            simulate_trace_cluster_admission(
                &cfg,
                &mut policies,
                &mut ctrls,
                router.as_mut(),
                &trace,
            )
        };
        let tel = Telemetry::new(TelemetryMode::Trace);
        let on = run(&tel);

        assert_eq!(
            off.recorder.records(),
            on.recorder.records(),
            "seed {seed}: cluster records"
        );
        assert_eq!(
            off.shard_rounds, on.shard_rounds,
            "seed {seed}: per-shard round timelines"
        );
        // routing decisions were traced and carry a full score vector
        let events = tel.events();
        let routes: Vec<&Event> = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Route { .. }))
            .collect();
        assert!(!routes.is_empty(), "seed {seed}: no route events traced");
        for e in &routes {
            let EventKind::Route { scores, .. } = &e.kind else {
                unreachable!()
            };
            assert_eq!(scores.len(), workers, "score vector covers every shard");
            assert!(e.shard < workers, "chosen shard in range");
        }
    }
}

// ---------------------------------------------------------------- conservation

#[test]
fn every_request_gets_exactly_one_terminal_finish_event() {
    let seed = 4u64;
    let mut cfg = paper_sim_config(seed);
    cfg.max_new_tokens = 32;
    let n = 300;
    // overload with tight deadlines so the SLO controller sheds some
    let trace = slo_fig6_trace(&const_prompt_pool(12), n, seed, 0.1, 1.5, 2.0);

    let tel = Telemetry::new(TelemetryMode::Trace);
    let mut policy = warm_model_based(&cfg, 30);
    let (rec, _) = simulate_trace_continuous_admission_tel(
        &cfg,
        &mut policy,
        &mut SloAware::default(),
        &trace,
        &tel,
    );

    let mut finishes: BTreeMap<u64, (usize, bool)> = BTreeMap::new();
    for e in tel.events() {
        if let EventKind::Finish { id, shed, .. } = e.kind {
            let entry = finishes.entry(id).or_insert((0, shed));
            entry.0 += 1;
            entry.1 = shed;
        }
    }
    assert_eq!(finishes.len(), n, "every trace id needs a terminal event");
    for (id, (count, _)) in &finishes {
        assert_eq!(*count, 1, "request {id}: exactly one terminal event");
    }
    let shed_finishes = finishes.values().filter(|(_, shed)| *shed).count();
    assert_eq!(
        shed_finishes,
        rec.shed_count(),
        "shed finish events must match the recorder"
    );
    assert!(shed_finishes > 0, "overload trace should shed something");
    for r in rec.records() {
        assert_eq!(
            finishes[&r.id].1, r.shed,
            "request {}: finish event disagrees with the record",
            r.id
        );
    }
}

// ------------------------------------------------------------- phase structure

/// `(start, end)` intervals, sorted, pairwise non-overlapping within eps.
fn assert_disjoint(mut spans: Vec<(f64, f64)>, what: &str) {
    spans.sort_by(|a, b| a.0.total_cmp(&b.0));
    for w in spans.windows(2) {
        assert!(
            w[1].0 >= w[0].1 - EPS,
            "{what}: [{:.6}, {:.6}] overlaps [{:.6}, {:.6}]",
            w[1].0,
            w[1].1,
            w[0].0,
            w[0].1
        );
    }
}

#[test]
fn phase_spans_nest_and_tile_rounds_per_shard() {
    let seed = 2u64;
    let mut cfg = paper_sim_config(seed);
    cfg.max_new_tokens = 32;
    let trace = slo_fig6_trace(&const_prompt_pool(12), 200, seed, 0.1, 1.5, 2.0);
    let workers = 2;

    let tel = Telemetry::new(TelemetryMode::Trace);
    let mut policies =
        replicate_policies(&PolicySpec::Fixed(2), None, workers).expect("no LUT needed");
    let mut ctrls = replicate_controllers(AdmissionSpec::SloAware, workers);
    let mut router = build_router(RouterSpec::JoinShortestQueue, seed);
    simulate_trace_cluster_admission_tel(
        &cfg,
        &mut policies,
        &mut ctrls,
        router.as_mut(),
        &trace,
        &tel,
    );

    let events = tel.events();
    let is_exec_phase = |e: &Event| {
        matches!(
            e.kind,
            EventKind::Phase {
                phase: PhaseKind::Draft | PhaseKind::Verify | PhaseKind::Accept
            }
        )
    };
    for shard in 0..workers {
        let rounds: Vec<&Event> = events
            .iter()
            .filter(|e| e.shard == shard && matches!(e.kind, EventKind::Round { .. }))
            .collect();
        let phases: Vec<&Event> = events
            .iter()
            .filter(|e| e.shard == shard && is_exec_phase(e))
            .collect();
        assert!(!rounds.is_empty(), "shard {shard} ran no rounds");
        assert!(!phases.is_empty(), "shard {shard} has no phase spans");

        assert_disjoint(
            rounds.iter().map(|e| (e.t, e.t + e.dur)).collect(),
            &format!("shard {shard} rounds"),
        );
        assert_disjoint(
            phases.iter().map(|e| (e.t, e.t + e.dur)).collect(),
            &format!("shard {shard} exec phases"),
        );

        // each round is tiled exactly by its draft/verify/accept spans
        for r in &rounds {
            let (lo, hi) = (r.t, r.t + r.dur);
            let inner: Vec<&&Event> = phases
                .iter()
                .filter(|p| p.t >= lo - EPS && p.t < hi - EPS)
                .collect();
            assert!(
                !inner.is_empty(),
                "shard {shard}: round at t={lo:.6} has no phase spans"
            );
            let mut covered = 0.0;
            for p in &inner {
                assert!(
                    p.t + p.dur <= hi + 1e-6,
                    "shard {shard}: phase escapes its round span"
                );
                covered += p.dur;
            }
            assert!(
                (covered - r.dur).abs() < 1e-6,
                "shard {shard}: phases cover {covered:.9}s of a {:.9}s round",
                r.dur
            );
        }

        // every phase span lies inside some round span (nesting)
        for p in &phases {
            assert!(
                rounds
                    .iter()
                    .any(|r| p.t >= r.t - EPS && p.t + p.dur <= r.t + r.dur + 1e-6),
                "shard {shard}: orphan phase at t={:.6}",
                p.t
            );
        }
    }
}

// ------------------------------------------------------------- export schemas

#[test]
fn chrome_trace_export_is_schema_valid_and_exporters_agree() {
    let seed = 6u64;
    let mut cfg = paper_sim_config(seed);
    cfg.max_new_tokens = 32;
    let trace = fig6_trace(&const_prompt_pool(12), 80, seed, 0.1);

    let tel = Telemetry::new(TelemetryMode::Trace);
    let mut policy = warm_model_based(&cfg, 30);
    let (_, _) = simulate_trace_continuous_admission_tel(
        &cfg,
        &mut policy,
        &mut SloAware::default(),
        &trace,
        &tel,
    );
    let events = tel.events();
    assert!(!events.is_empty());

    // Chrome trace_event document: every record has name/ph/pid, spans
    // ("X") carry ts + dur, and the whole thing survives a JSON round-trip
    let doc = export::chrome_trace(&events);
    let trace_events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!trace_events.is_empty());
    let mut seen_span = false;
    for e in trace_events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        assert!(
            ["M", "X", "i", "C"].contains(&ph),
            "unexpected phase type {ph:?}"
        );
        assert!(!e.get("name").unwrap().as_str().unwrap().is_empty());
        e.get("pid").unwrap().as_usize().unwrap();
        if ph != "M" {
            assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            e.get("tid").unwrap().as_usize().unwrap();
        }
        if ph == "X" {
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            seen_span = true;
        }
    }
    assert!(seen_span, "a decode run must produce span records");
    let reparsed = Json::parse(&doc.pretty()).expect("chrome trace must be valid JSON");
    assert_eq!(
        reparsed.get("traceEvents").unwrap().as_arr().unwrap().len(),
        trace_events.len()
    );

    // JSONL: one valid JSON object per event, tagged with its kind
    let jsonl = export::events_jsonl(&events);
    assert_eq!(jsonl.lines().count(), events.len());
    for line in jsonl.lines() {
        let obj = Json::parse(line).expect("each JSONL line parses");
        obj.get("ev").unwrap().as_str().unwrap();
        obj.get("t").unwrap().as_f64().unwrap();
    }

    // Prometheus text: typed metric families, and the round counter a
    // decode run must have bumped
    let prom = export::prometheus_text(&tel.registry());
    assert!(prom.contains("# TYPE "), "missing TYPE headers:\n{prom}");
    assert!(
        prom.contains("# TYPE specbatch_rounds_total counter"),
        "missing round counter family:\n{prom}"
    );
}

#[test]
fn bench_fig6_report_matches_the_experiment_outcome() {
    let tel = Telemetry::new(TelemetryMode::Trace);
    let cfg = ServerConfig {
        telemetry: tel.clone(),
        ..stub_server_cfg(SchedulingMode::Continuous, KvLayout::Paged)
    };
    let trace = fig6_trace(&stub_prompt_pool(), 48, 11, 0.002);
    let out = run_experiment(
        Backend::Stub(StubSpec::default()),
        cfg,
        PolicySpec::Fixed(2),
        None,
        &trace,
    )
    .expect("stub experiment");

    let config = Json::obj(vec![
        ("bench", Json::Str("fig6".into())),
        ("requests", Json::Num(48.0)),
    ]);
    let report = bench::bench_report("fig6", &out.recorder, &out.timeline, config);

    // write + parse back: exactly what BENCH_fig6.json would contain
    let dir = std::env::temp_dir().join(format!("specbatch_bench_fig6_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_fig6.json");
    report.write_file(&path).unwrap();
    let doc = Json::parse_file(&path).unwrap();
    let _ = std::fs::remove_dir_all(&dir);

    let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 * a.abs().max(b.abs()).max(1.0);
    assert_eq!(doc.get("name").unwrap().as_str().unwrap(), "fig6");
    assert_eq!(
        doc.get("requests").unwrap().as_usize().unwrap(),
        out.recorder.len()
    );
    let slo = out.recorder.slo_attainment();
    assert_eq!(
        doc.get("completed").unwrap().as_usize().unwrap(),
        slo.completed
    );
    assert_eq!(doc.get("shed").unwrap().as_usize().unwrap(), slo.shed);
    let ptl = doc.get("per_token_latency_s").unwrap();
    assert!(close(
        ptl.get("mean").unwrap().as_f64().unwrap(),
        out.recorder.mean_per_token_latency()
    ));
    assert!(ptl.get("p50").unwrap().as_f64().unwrap() > 0.0);
    assert!(
        ptl.get("p99").unwrap().as_f64().unwrap()
            >= ptl.get("p50").unwrap().as_f64().unwrap()
    );
    assert!(close(
        doc.get("tokens_per_s").unwrap().as_f64().unwrap(),
        out.recorder.throughput_tokens_per_s()
    ));
    assert_eq!(
        doc.get("rounds").unwrap().as_usize().unwrap(),
        out.timeline.len()
    );
    let slo_doc = doc.get("slo").unwrap();
    assert_eq!(
        slo_doc.get("met").unwrap().as_usize().unwrap()
            + slo_doc.get("missed").unwrap().as_usize().unwrap(),
        slo.deadlined
    );
    assert!(!doc
        .get("config_fingerprint")
        .unwrap()
        .as_str()
        .unwrap()
        .is_empty());
    // fingerprint is over the config only — reproducible from the doc
    assert_eq!(
        doc.get("config_fingerprint").unwrap().as_str().unwrap(),
        bench::config_fingerprint(doc.get("config").unwrap())
    );

    // the live handle also saw the run: one terminal finish per request
    let finish_count = tel
        .events()
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Finish { .. }))
        .count();
    assert_eq!(finish_count, out.recorder.len());
}

// ------------------------------------------------------------- common epoch

/// The threaded cluster path rebases the telemetry clock to the
/// experiment epoch, so every shard's track in the exported Chrome trace
/// starts near t=0 — even when the `Telemetry` handle was created long
/// before the run.  Without `rebase_to_now` every timestamp would carry
/// the handle's age as a constant offset (here: an injected 300ms gap).
#[test]
fn threaded_cluster_traces_share_a_common_rebased_epoch() {
    let tel = Telemetry::new(TelemetryMode::Trace);
    // age the handle: its internal clock now reads ~0.3s
    std::thread::sleep(std::time::Duration::from_millis(300));

    let cfg = ServerConfig {
        telemetry: tel.clone(),
        workers: 2,
        ..stub_server_cfg(SchedulingMode::Continuous, KvLayout::Paged)
    };
    let trace = fig6_trace(&stub_prompt_pool(), 32, 9, 0.002);
    run_experiment(
        Backend::Stub(StubSpec::default()),
        cfg,
        PolicySpec::Fixed(2),
        None,
        &trace,
    )
    .expect("threaded cluster experiment");

    let events = tel.events();
    assert!(!events.is_empty(), "trace mode must record the cluster run");
    let t_min = events.iter().map(|e| e.t).fold(f64::INFINITY, f64::min);
    assert!(
        (0.0..0.25).contains(&t_min),
        "trace epoch was not rebased to the run start: first event at t={t_min:.3}s \
         (the 300ms handle age leaked into the timeline)"
    );

    // both shard tracks exist and share the origin — neither carries a
    // private offset
    for shard in 0..2usize {
        let first = events
            .iter()
            .filter(|e| e.shard == shard && matches!(e.kind, EventKind::Round { .. }))
            .map(|e| e.t)
            .fold(f64::INFINITY, f64::min);
        assert!(first.is_finite(), "shard {shard} ran no rounds");
        assert!(
            first < 30.0,
            "shard {shard}: first round at t={first:.3}s is not on the run epoch"
        );
    }

    // the Chrome export inherits the common epoch: the earliest span/
    // instant timestamp is the rebased one (microseconds)
    let doc = export::chrome_trace(&events);
    let ts_min = doc
        .get("traceEvents")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str().unwrap() != "M")
        .map(|e| e.get("ts").unwrap().as_f64().unwrap())
        .fold(f64::INFINITY, f64::min);
    assert!(
        ts_min < 250_000.0,
        "chrome trace ts values carry a stale epoch offset: min ts = {ts_min:.0}us"
    );
}
