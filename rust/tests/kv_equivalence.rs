//! Cross-layout equivalence + block-accounting acceptance tests for the
//! paged KV block manager (`specbatch::kvcache`).
//!
//! The layout seam must be **observationally invisible**: under randomized
//! admit/retire/reshape schedules over seeded traces, `Dense` and `Paged`
//! engines must produce bit-identical generated tokens and acceptance
//! counts — only the ingestion call pattern (and therefore cost) may
//! differ.  On top of that, the pinned reshape test asserts the tentpole
//! payoff directly: **zero** re-prefilled tokens across an epoch reshape
//! under `Paged` vs a positive count under `Dense`, and the leak tests
//! assert every block returns to the free list after every stub e2e
//! experiment, mid-stream retirement and reshape paths included.

use specbatch::config::PolicySpec;
use specbatch::engine::{AdmitRequest, Engine, EngineConfig};
use specbatch::kvcache::{KvBlockStats, KvLayout};
use specbatch::metrics::RoundEvent;
use specbatch::policy::Fixed;
use specbatch::server::{run_experiment, Backend, SchedulingMode, ServerConfig};
use specbatch::testkit::harness::{
    assert_conserves_ids, quick_stub_trace, stub_server_cfg,
};
use specbatch::testkit::stub::StubSpec;
use specbatch::util::prng::Pcg64;
use specbatch::{
    batcher::{BatchRequest, BatcherConfig, ContinuousBatcher},
    config::RouterSpec,
};

fn engine(layout: KvLayout) -> Engine<'static> {
    Engine::stub(
        StubSpec::default(),
        EngineConfig {
            kv_layout: layout,
            ..EngineConfig::default()
        },
    )
    .unwrap()
}

// ---------------------------------------------------------------- property

/// One randomized serving schedule: arrival step indices + prompts, and
/// the batcher knobs.  Derived deterministically from a seed.
struct Schedule {
    max_batch: usize,
    max_new: usize,
    arrivals: Vec<(usize, u64, Vec<i32>)>,
}

fn random_schedule(seed: u64) -> Schedule {
    let mut rng = Pcg64::with_stream(seed, 0xE9);
    let n = 6 + rng.next_below(9); // 6..=14 requests
    let max_batch = 3 + rng.next_below(6); // 3..=8 live rows
    let max_new = 6 + rng.next_below(15); // 6..=20 tokens each
    let mut arrivals = Vec::with_capacity(n);
    let mut step = 0usize;
    for id in 0..n {
        // gaps of 0..=3 rounds: bursts (reshapes) and lulls (retirement)
        step += rng.next_below(4);
        let plen = 1 + rng.next_below(6);
        let prompt: Vec<i32> = (0..plen).map(|_| 4 + rng.next_below(56) as i32).collect();
        arrivals.push((step, id as u64, prompt));
    }
    Schedule {
        max_batch,
        max_new,
        arrivals,
    }
}

/// Everything observable about one layout's run of a schedule: finished
/// tokens per request, the timeline with clock/cost columns projected
/// out, the (reingested, remapped) totals and the block accounting.
struct RunOutcome {
    finished: Vec<(u64, Vec<i32>)>,
    rounds: Vec<(usize, usize, usize, usize, usize)>,
    reingested: usize,
    remapped: usize,
    kv: Option<KvBlockStats>,
}

fn run_schedule(s: &Schedule, layout: KvLayout) -> RunOutcome {
    let mut e = engine(layout);
    let mut policy = Fixed(3);
    let mut batcher = ContinuousBatcher::new(BatcherConfig {
        max_batch: s.max_batch,
        max_new_tokens: s.max_new,
    });
    let mut pending = s.arrivals.clone();
    let mut finished: Vec<(u64, Vec<i32>)> = Vec::new();
    let mut step = 0usize;
    while batcher.has_work() || !pending.is_empty() {
        pending.retain(|(at, id, prompt)| {
            if *at <= step {
                batcher.enqueue(BatchRequest::new(*id, prompt.clone(), *at as f64 * 1e-3));
                false
            } else {
                true
            }
        });
        for f in batcher.step(&mut e, &mut policy, step as f64 * 1e-3).unwrap() {
            finished.push((f.id, f.tokens));
        }
        step += 1;
        assert!(step < 10_000, "batcher failed to drain");
    }
    finished.sort_by_key(|(id, _)| *id);
    let project = |e: &RoundEvent| (e.epoch, e.live, e.queued, e.s, e.accepted);
    let (reingested, remapped) = batcher.kv_transfer_totals();
    e.clear_prefix_cache(); // cached prefix blocks are not leaks
    RunOutcome {
        finished,
        rounds: batcher.timeline.iter().map(project).collect(),
        reingested,
        remapped,
        kv: e.kv_block_stats(),
    }
}

/// The equivalence property over >= 3 seeds (the acceptance criterion
/// runs five): same schedule, both layouts, bit-identical tokens and
/// per-round acceptance counts; the carried-token totals mirror each
/// other (dense re-ingests exactly the tokens paged remaps); and the
/// paged pools come back leak-free every time.
#[test]
fn dense_and_paged_agree_on_randomized_admit_retire_reshape_schedules() {
    // five random schedules plus one crafted burst that reshapes for
    // certain: one long request decodes alone, then five arrivals force
    // the epoch into a larger bucket with a carried row
    let crafted = Schedule {
        max_batch: 8,
        max_new: 20,
        arrivals: (0..6u64)
            .map(|id| {
                (
                    if id == 0 { 0 } else { 3 },
                    id,
                    vec![5 + id as i32],
                )
            })
            .collect(),
    };
    let schedules: Vec<Schedule> = [0x11u64, 0x22, 0x33, 0x44, 0x55]
        .iter()
        .map(|&s| random_schedule(s))
        .chain(std::iter::once(crafted))
        .collect();
    let mut any_reshape = false;
    for (idx, schedule) in schedules.iter().enumerate() {
        let dense = run_schedule(schedule, KvLayout::Dense);
        let paged = run_schedule(schedule, KvLayout::Paged);

        assert_eq!(
            dense.finished, paged.finished,
            "schedule {idx}: generated tokens diverged between layouts"
        );
        assert_eq!(
            dense.rounds, paged.rounds,
            "schedule {idx}: round structure / acceptance counts diverged"
        );
        assert_eq!(
            dense.remapped, 0,
            "schedule {idx}: a dense run cannot remap blocks"
        );
        assert_eq!(
            paged.reingested, 0,
            "schedule {idx}: a paged run must never re-ingest carried tokens"
        );
        assert_eq!(
            paged.remapped, dense.reingested,
            "schedule {idx}: paged must transfer exactly the tokens dense re-feeds"
        );
        assert!(dense.kv.is_none());
        let kv = paged.kv.expect("paged engine reports block stats");
        assert!(kv.is_leak_free(), "schedule {idx}: leaked blocks: {kv:?}");
        any_reshape |= dense.reingested > 0;
    }
    assert!(
        any_reshape,
        "no schedule exercised a carried reshape — the property lost its teeth"
    );
}

// ------------------------------------------------------------ pinned reshape

/// The tentpole payoff, pinned at the engine seam: an epoch reshape
/// re-prefills a positive number of carried tokens under `Dense` and
/// exactly zero under `Paged`, with bit-identical outputs and strictly
/// fewer LLM calls on the paged side.
#[test]
fn epoch_reshape_reingests_zero_tokens_under_paged_and_more_under_dense() {
    let run = |layout: KvLayout| {
        let mut e = engine(layout);
        let mut policy = Fixed(3);
        let mut st = e
            .prefill_rows(&[vec![5, 9], vec![7, 8]], 2, true, 24)
            .unwrap();
        for _ in 0..4 {
            e.decode_round(&mut st, &mut policy).unwrap();
        }
        // the batcher's reshape sequence: export, release, prefill the
        // larger bucket with a fresh row, re-admit the carried rows
        let mut exported = Vec::new();
        e.export_rows(&st, &mut exported);
        let carried: Vec<AdmitRequest> = exported.into_iter().map(|(_, r)| r).collect();
        assert_eq!(carried.len(), 2);
        e.release_state(&mut st);
        let mut st2 = e.prefill_rows(&[vec![40, 41]], 4, true, 24).unwrap();
        e.admit_rows(&mut st2, carried).unwrap();
        let reingested = st2.stats.reingested_tokens;
        let remapped = st2.stats.remapped_tokens;
        let admit_llm_calls = st2.stats.llm_calls;
        while st2.has_live() {
            e.decode_round(&mut st2, &mut policy).unwrap();
        }
        let mut tokens: Vec<(usize, Vec<i32>)> = e
            .retire_finished(&mut st2)
            .into_iter()
            .map(|r| (r.slot, r.tokens))
            .collect();
        tokens.sort_by_key(|(slot, _)| *slot);
        e.release_state(&mut st2);
        if let Some(stats) = e.kv_block_stats() {
            assert!(stats.is_leak_free(), "leaked blocks: {stats:?}");
        }
        (reingested, remapped, admit_llm_calls, tokens)
    };

    let (re_d, rm_d, calls_d, tokens_d) = run(KvLayout::Dense);
    let (re_p, rm_p, calls_p, tokens_p) = run(KvLayout::Paged);

    assert!(re_d > 0, "dense reshape must re-prefill the carried contexts");
    assert_eq!(rm_d, 0);
    assert_eq!(re_p, 0, "paged reshape must re-prefill exactly zero tokens");
    assert_eq!(
        rm_p, re_d,
        "the remap transfers exactly the tokens dense re-feeds"
    );
    assert!(
        calls_p < calls_d,
        "paged admission must skip the ingest verify calls ({calls_p} vs {calls_d})"
    );
    assert_eq!(tokens_d, tokens_p, "reshape path changed the outputs");
}

// ------------------------------------------------------------------- leaks

/// After every stub e2e experiment — static, continuous (mid-stream
/// retirement + reshape), and the threaded cluster — the block pools'
/// free-list cardinality equals their capacity: nothing leaked, nothing
/// double-freed.
#[test]
fn stub_e2e_experiments_leave_every_block_on_the_free_list() {
    for mode in [SchedulingMode::Static, SchedulingMode::Continuous] {
        let out = run_experiment(
            Backend::Stub(StubSpec::default()),
            stub_server_cfg(mode, KvLayout::Paged),
            PolicySpec::Fixed(2),
            None,
            &quick_stub_trace(14, 9),
        )
        .expect("experiment");
        assert_conserves_ids(&out.recorder, 14);
        let stats = out.kv_blocks.expect("paged run reports block stats");
        assert!(stats.is_leak_free(), "{mode:?} leaked blocks: {stats:?}");
        assert!(stats.peak_in_use > 0, "{mode:?} never allocated a block");
    }

    // the threaded cluster merges per-shard pools into one leak check
    let cfg = ServerConfig {
        workers: 2,
        router: RouterSpec::RoundRobin,
        ..stub_server_cfg(SchedulingMode::Continuous, KvLayout::Paged)
    };
    let out = run_experiment(
        Backend::Stub(StubSpec::default()),
        cfg,
        PolicySpec::Fixed(2),
        None,
        &quick_stub_trace(16, 21),
    )
    .expect("cluster experiment");
    assert_conserves_ids(&out.recorder, 16);
    let stats = out.kv_blocks.expect("paged cluster reports merged stats");
    assert!(stats.is_leak_free(), "cluster leaked blocks: {stats:?}");
    for shard in &out.shards {
        let s = shard.kv_blocks.expect("each shard reports its pool");
        assert!(s.is_leak_free(), "shard {} leaked: {s:?}", shard.shard);
    }
}
