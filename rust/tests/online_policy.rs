//! The feedback-driven-policy acceptance test (the tentpole payoff): on a
//! continuous-batching trace whose draft acceptance **drifts mid-trace**,
//! the offline LUT — profiled for the pre-drift workload and now stale —
//! and every fixed speculation length lose to the online [`ModelBased`]
//! policy in mean request latency, and after the drift the online policy
//! re-converges to within ±1 of the oracle `s_opt`.
//!
//! Scenario: the pre-drift workload has high draft acceptance
//! (l(s) = 0.9·s^0.8 — long speculation pays), the post-drift workload
//! has collapsed acceptance (l(s) = 0.6·s^0.05 — barely half a draft
//! accepted regardless of s, so the oracle drops to s = 1).  Long fixed
//! lengths saturate the server after the drift; short fixed lengths waste
//! the easy pre-drift speedup; the stale LUT keeps over-speculating at
//! every batch size.  Only the online policy tracks both regimes.

use specbatch::dataset::Prompt;
use specbatch::policy::{
    Fixed, LutAdaptive, ModelBased, ModelBasedConfig, NoSpec, SpeculationPolicy,
};
use specbatch::simulator::{
    oracle_s_opt, simulate_trace_continuous, simulated_lut, AcceptanceDrift, AcceptanceProcess,
    CostModel, GpuProfile, ModelProfile, SimConfig,
};
use specbatch::traffic::{Trace, TrafficPattern};

const DRIFT_AT: f64 = 60.0;
const N_REQUESTS: usize = 600;

fn phase_a() -> AcceptanceProcess {
    AcceptanceProcess::PowerLaw { c: 0.9, gamma: 0.8 }
}

fn phase_b() -> AcceptanceProcess {
    AcceptanceProcess::PowerLaw {
        c: 0.6,
        gamma: 0.05,
    }
}

/// Paper-scale config whose acceptance drifts from `phase_a` to
/// `phase_b` at `DRIFT_AT` virtual seconds.
fn drift_cfg() -> SimConfig {
    let mut cfg = SimConfig::paper_default(
        CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
    );
    cfg.acceptance = phase_a();
    cfg.drift = Some(AcceptanceDrift {
        at: DRIFT_AT,
        after: phase_b(),
    });
    cfg.seed = 7;
    cfg
}

/// The LUT an offline profiling pass would have produced BEFORE the
/// drift (built against the pre-drift acceptance only).
fn stale_lut(cfg: &SimConfig) -> specbatch::scheduler::Lut {
    let mut pre = cfg.clone();
    pre.drift = None;
    simulated_lut(&pre, &[1, 2, 4, 8, 16], 8, 80)
}

fn drift_trace() -> Trace {
    let pool = vec![Prompt {
        ids: vec![1; 16],
        text: String::new(),
    }];
    Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.2,
            cv: 1.0,
        },
        &pool,
        N_REQUESTS,
        42,
    )
}

fn mean_latency(cfg: &SimConfig, policy: &mut dyn SpeculationPolicy, trace: &Trace) -> f64 {
    let (rec, _) = simulate_trace_continuous(cfg, policy, trace);
    assert_eq!(rec.len(), trace.len(), "request conservation");
    rec.summary().mean
}

#[test]
fn scenario_preconditions_oracle_shrinks_after_drift() {
    let cfg = drift_cfg();
    // pre-drift the oracle wants long speculation at small batch...
    assert!(
        oracle_s_opt(&cfg, &phase_a(), 1, 8, 80) >= 5,
        "pre-drift small-batch oracle should want long speculation"
    );
    // ...post-drift it collapses to (near) no speculation at every batch
    for live in [1usize, 2, 4, 8, 16] {
        let s = oracle_s_opt(&cfg, &phase_b(), live, 8, 80);
        assert!(s <= 2, "post-drift oracle at live={live} is {s}, expected <= 2");
    }
}

#[test]
fn model_based_beats_stale_lut_and_every_fixed_s_under_acceptance_drift() {
    let cfg = drift_cfg();
    let lut = stale_lut(&cfg);
    let trace = drift_trace();

    let model_mean = mean_latency(&cfg, &mut ModelBased::new(lut.clone()), &trace);
    let stale_mean = mean_latency(&cfg, &mut LutAdaptive(lut.clone()), &trace);
    let nospec_mean = mean_latency(&cfg, &mut NoSpec, &trace);

    assert!(
        model_mean < stale_mean,
        "online policy ({model_mean:.3}s) must beat the stale LUT ({stale_mean:.3}s)"
    );
    assert!(
        model_mean < nospec_mean,
        "online policy ({model_mean:.3}s) must beat no-spec ({nospec_mean:.3}s)"
    );
    for s in [1usize, 2, 3, 4, 6, 8] {
        let fixed_mean = mean_latency(&cfg, &mut Fixed(s), &trace);
        assert!(
            model_mean < fixed_mean,
            "online policy ({model_mean:.3}s) must beat fixed-{s} ({fixed_mean:.3}s)"
        );
    }
}

#[test]
fn model_based_reconverges_to_the_oracle_after_the_drift() {
    let cfg = drift_cfg();
    let lut = stale_lut(&cfg);
    let trace = drift_trace();
    let mut policy = ModelBased::new(lut);
    let (rec, rounds) = simulate_trace_continuous(&cfg, &mut policy, &trace);
    assert_eq!(rec.len(), trace.len());

    // give the windowed fits time to turn over, then compare every round's
    // chosen s against the oracle for the post-drift acceptance at that
    // round's live batch size (ctx ~ prompt + half the generation budget)
    let settled: Vec<_> = rounds.iter().filter(|e| e.t >= DRIFT_AT + 20.0).collect();
    assert!(
        settled.len() >= 50,
        "too few post-drift rounds to judge convergence: {}",
        settled.len()
    );
    let within_one = settled
        .iter()
        .filter(|e| {
            let oracle = oracle_s_opt(&cfg, &phase_b(), e.live, 8, 80) as i64;
            (e.s as i64 - oracle).abs() <= 1
        })
        .count();
    let frac = within_one as f64 / settled.len() as f64;
    assert!(
        frac >= 0.7,
        "only {:.0}% of post-drift rounds within +-1 of the oracle s_opt",
        frac * 100.0
    );

    // the re-fitted acceptance curve reflects the collapsed regime
    let acc = policy.fitted_acceptance().expect("fits are warm");
    assert!(
        acc.l(1.0) < 0.8,
        "post-drift fitted l(1) = {:.3} should be far below the pre-drift 0.9",
        acc.l(1.0)
    );
}

/// The CUSUM satellite payoff: at SMALL batch the sliding acceptance
/// window turns over one sample per round, so after a drift the passive
/// fits stay contaminated for hundreds of rounds — the changepoint
/// detector flushes the window and re-converges in a warmup instead.
/// Sparse traffic (live mostly 1) + the same drift mechanism, comparing
/// the detector on (default) against off (`cusum_h = 0`): in the 40
/// virtual seconds after the drift the detector-on policy tracks the
/// post-drift oracle clearly more often.
#[test]
fn cusum_flush_reconverges_faster_than_the_passive_window_at_small_batch() {
    const SPARSE_DRIFT_AT: f64 = 120.0;
    let mut cfg = drift_cfg();
    cfg.drift = Some(AcceptanceDrift {
        at: SPARSE_DRIFT_AT,
        after: phase_b(),
    });
    let lut = stale_lut(&cfg);
    let pool = vec![Prompt {
        ids: vec![1; 16],
        text: String::new(),
    }];
    let trace = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 1.2,
            cv: 1.0,
        },
        &pool,
        400,
        42,
    );

    let frac_tracking = |policy: &mut ModelBased| -> (f64, f64) {
        let (rec, rounds) = simulate_trace_continuous(&cfg, policy, &trace);
        assert_eq!(rec.len(), trace.len());
        let window: Vec<_> = rounds
            .iter()
            .filter(|e| (SPARSE_DRIFT_AT..SPARSE_DRIFT_AT + 40.0).contains(&e.t))
            .collect();
        assert!(window.len() >= 200, "too few post-drift rounds: {}", window.len());
        let within = window
            .iter()
            .filter(|e| {
                let oracle = oracle_s_opt(&cfg, &phase_b(), e.live, 8, 80) as i64;
                (e.s as i64 - oracle).abs() <= 1
            })
            .count();
        (within as f64 / window.len() as f64, rec.summary().mean)
    };

    let mut with = ModelBased::new(lut.clone());
    let (frac_with, mean_with) = frac_tracking(&mut with);
    let mut without = ModelBased::with_config(
        lut,
        ModelBasedConfig {
            cusum_h: 0.0, // detector off: the passive window only
            ..ModelBasedConfig::default()
        },
    );
    let (frac_without, mean_without) = frac_tracking(&mut without);

    assert!(
        with.drift_flushes() >= 1,
        "the detector must fire on the drift"
    );
    assert_eq!(without.drift_flushes(), 0, "disabled detector must not fire");
    assert!(
        frac_with >= frac_without + 0.05,
        "flush must re-converge clearly faster: with {frac_with:.2} vs \
         without {frac_without:.2}"
    );
    assert!(
        frac_with >= 0.85,
        "detector-on tracking too weak right after the drift: {frac_with:.2}"
    );
    // the faster model pivot must not cost latency overall
    assert!(
        mean_with <= mean_without * 1.05,
        "cusum flushes hurt end-to-end latency: {mean_with:.3} vs {mean_without:.3}"
    );
}
