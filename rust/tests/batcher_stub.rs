//! End-to-end server/client integration on the **stub backend**: real
//! worker thread, real message queues, real Gamma traffic — and no
//! artifacts, so this runs in the default build/CI.  Covers both
//! scheduling modes and the stub adaptive-LUT fallback.  Shared
//! scaffolding lives in `specbatch::testkit::harness`.

use specbatch::config::PolicySpec;
use specbatch::kvcache::KvLayout;
use specbatch::server::{run_experiment, Backend, SchedulingMode, ServerConfig};
use specbatch::testkit::harness::{
    assert_conserves_ids, assert_no_block_leaks, quick_stub_trace, stub_server_cfg,
};
use specbatch::testkit::stub::StubSpec;
use specbatch::traffic::Trace;

fn stub_cfg(mode: SchedulingMode) -> ServerConfig {
    // the default layout honours the SPECBATCH_KV_LAYOUT matrix override
    stub_server_cfg(mode, KvLayout::default_layout())
}

fn quick_trace(n: usize, seed: u64) -> Trace {
    quick_stub_trace(n, seed)
}

#[test]
fn stub_server_static_accounts_every_request() {
    let trace = quick_trace(12, 3);
    let out = run_experiment(
        Backend::Stub(StubSpec::default()),
        stub_cfg(SchedulingMode::Static),
        PolicySpec::Fixed(2),
        None,
        &trace,
    )
    .expect("experiment");
    assert!(out.lut.is_none());
    assert!(out.policy_snapshot.is_none());
    let (rec, rounds) = (&out.recorder, &out.timeline);
    assert_conserves_ids(rec, 12);
    for r in rec.records() {
        assert!(r.finished_at > r.started_at, "finish before start");
        assert_eq!(r.tokens, 8, "stub never emits <eos>");
        assert!(r.batch >= 1 && r.batch <= 4);
    }
    // static mode also surfaces a per-round timeline
    assert!(!rounds.is_empty());
    assert!(rounds.iter().all(|e| e.live >= 1 && e.live <= 4));
    assert_no_block_leaks(&out);
}

#[test]
fn stub_server_continuous_accounts_every_request_with_timeline() {
    let trace = quick_trace(16, 7);
    let out = run_experiment(
        Backend::Stub(StubSpec::default()),
        stub_cfg(SchedulingMode::Continuous),
        PolicySpec::Fixed(2),
        None,
        &trace,
    )
    .expect("experiment");
    let (rec, rounds) = (&out.recorder, &out.timeline);
    assert_conserves_ids(rec, 16);
    for r in rec.records() {
        assert_eq!(r.tokens, 8);
        assert!(r.batch >= 1 && r.batch <= 4, "live cap violated: {}", r.batch);
        assert!(r.spec_len <= 2);
    }
    assert!(!rounds.is_empty(), "continuous mode records every round");
    assert!(rounds.iter().all(|e| e.live >= 1 && e.live <= 4));
    assert!(rounds.iter().all(|e| e.s <= 2));
    // round times never go backwards, and the new feedback columns are
    // populated
    for w in rounds.windows(2) {
        assert!(w[1].t >= w[0].t - 1e-9);
    }
    assert!(rounds.iter().all(|e| e.round_cost >= 0.0));
    assert!(rounds.iter().all(|e| e.accepted <= e.s * e.live));
    assert_no_block_leaks(&out);
}

#[test]
fn stub_server_adaptive_falls_back_to_the_simulated_lut() {
    let trace = quick_trace(6, 11);
    let out = run_experiment(
        Backend::Stub(StubSpec::default()),
        stub_cfg(SchedulingMode::Continuous),
        PolicySpec::Adaptive,
        None,
        &trace,
    )
    .expect("experiment");
    assert_eq!(out.recorder.len(), 6);
    let lut = out.lut.expect("adaptive must yield a LUT");
    for (&b, &s) in lut.entries() {
        assert!(b >= 1 && b <= 4, "bucket {b} beyond max_batch");
        assert!(s <= 8, "absurd speculation length {s} for bucket {b}");
    }
    assert_no_block_leaks(&out);
}

#[test]
fn both_modes_generate_identical_tokens_per_request() {
    // losslessness through the whole server stack: scheduling must never
    // change WHAT is generated, only WHEN
    let trace = quick_trace(10, 19);
    let run = |mode| {
        let out = run_experiment(
            Backend::Stub(StubSpec::default()),
            stub_cfg(mode),
            PolicySpec::Fixed(3),
            None,
            &trace,
        )
        .expect("experiment");
        let mut counts: Vec<(u64, usize)> =
            out.recorder.records().iter().map(|r| (r.id, r.tokens)).collect();
        counts.sort_unstable();
        counts
    };
    // the stub is deterministic per prompt, so token COUNTS must agree;
    // exact token equality is asserted at the batcher level (unit tests)
    assert_eq!(run(SchedulingMode::Static), run(SchedulingMode::Continuous));
}

#[test]
fn stub_server_model_based_serves_and_reports_a_snapshot() {
    // enough traffic that the online policy ingests real feedback
    let trace = quick_trace(20, 23);
    let out = run_experiment(
        Backend::Stub(StubSpec::default()),
        stub_cfg(SchedulingMode::Continuous),
        PolicySpec::ModelBased,
        None,
        &trace,
    )
    .expect("experiment");
    assert_conserves_ids(&out.recorder, 20);
    // the online policy is seeded with a cold-start LUT and reports a
    // fitted-model snapshot at shutdown
    assert!(out.lut.is_some(), "model-based must be seeded with a LUT");
    let snap = out.policy_snapshot.expect("model-based reports a snapshot");
    assert_eq!(
        snap.get("policy").unwrap().as_str().unwrap(),
        "model-based"
    );
    // every response is still lossless-complete (stub never emits <eos>)
    for r in out.recorder.records() {
        assert_eq!(r.tokens, 8);
    }
    assert_no_block_leaks(&out);
}
