//! Cross-validation of the three worlds that must agree on the paper's
//! phenomena: the analytic model (Sec. 3.3), the GPU simulator, and the
//! scheduler policy layer.  No artifacts required — this exercises the
//! paper's *theory* end to end.

use specbatch::analytic::{AcceptanceModel, StepCostModel, TotalTimeModel};
use specbatch::dataset::Prompt;
use specbatch::policy::{Fixed, LutAdaptive, NoSpec};
use specbatch::simulator::{
    batch_service_time, simulate_trace, simulated_lut, AcceptanceProcess, CostModel,
    GpuProfile, ModelProfile, SimConfig,
};
use specbatch::traffic::{Trace, TrafficPattern};
use specbatch::util::prng::Pcg64;

fn sim_cfg() -> SimConfig {
    SimConfig::paper_default(
        CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
    )
}

/// The analytic s_opt (Eq. 12, fed with the simulator's own fitted α_b/β
/// and the paper acceptance curve) must track the simulator's
/// grid-searched optimum within ±2 across batch sizes.
#[test]
fn analytic_sopt_tracks_simulated_optimum() {
    let cfg = sim_cfg();
    let acceptance = AcceptanceModel::paper();
    let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16, 32], 8, 96);
    for &b in &[1usize, 2, 4, 8, 16, 32] {
        let (alpha, beta) = cfg.llm.linearize(b, 8, 96);
        let cost = StepCostModel {
            batch: b,
            alpha,
            beta,
            t_ssm: cfg.ssm.t_draft(b, 96),
            r2: 1.0,
        };
        let model = TotalTimeModel { acceptance, cost };
        let predicted = model.s_opt(8) as i64;
        let simulated = lut.lookup(b) as i64;
        assert!(
            (predicted - simulated).abs() <= 2,
            "b={b}: analytic s_opt {predicted} vs simulated {simulated}"
        );
    }
}

/// Adding the SSM's per-draft cost must never *increase* the analytic
/// optimal speculation length.
#[test]
fn costlier_draft_model_shrinks_sopt() {
    let cfg = sim_cfg();
    let acceptance = AcceptanceModel::paper();
    let (alpha, beta) = cfg.llm.linearize(4, 8, 96);
    let cheap = TotalTimeModel {
        acceptance,
        cost: StepCostModel {
            batch: 4,
            alpha,
            beta,
            t_ssm: 0.0,
            r2: 1.0,
        },
    };
    let dear = TotalTimeModel {
        acceptance,
        cost: StepCostModel {
            batch: 4,
            alpha,
            beta,
            t_ssm: beta * 0.5, // absurdly expensive draft model
            r2: 1.0,
        },
    };
    assert!(dear.s_opt(8) <= cheap.s_opt(8));
}

/// Fig. 4's structure in the simulator: the adaptive speedup over
/// no-spec shrinks monotonically-ish as batch grows, staying > 1.
#[test]
fn speedup_decreases_with_batch() {
    let cfg = sim_cfg();
    let lut = simulated_lut(&cfg, &[1, 4, 16], 8, 80);
    let mut rng = Pcg64::new(2);
    let mut prev = f64::INFINITY;
    for &b in &[1usize, 4, 16] {
        let plens = vec![16usize; b];
        let (t0, _, _) = batch_service_time(&cfg, &mut NoSpec, &plens, 0.0, &mut rng);
        let (t1, _, _) = batch_service_time(
            &cfg,
            &mut LutAdaptive(lut.clone()),
            &plens,
            0.0,
            &mut rng,
        );
        let speedup = t0 / t1;
        assert!(speedup > 1.05, "b={b}: speedup {speedup} too small");
        assert!(
            speedup <= prev * 1.15,
            "b={b}: speedup {speedup} grew vs {prev}"
        );
        prev = speedup;
    }
}

/// Queueing sanity at the two traffic extremes (Fig. 5's axes): intense
/// traffic must queue, sparse must not.
#[test]
fn queueing_delay_appears_only_under_load() {
    let cfg = sim_cfg();
    let pool = vec![Prompt {
        ids: vec![1; 16],
        text: String::new(),
    }];
    let sparse = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 30.0,
            cv: 0.5,
        },
        &pool,
        40,
        1,
    );
    let rec = simulate_trace(&cfg, &mut Fixed(2), &sparse);
    let mean_queue: f64 = rec
        .records()
        .iter()
        .map(|r| r.queue_delay())
        .sum::<f64>()
        / rec.len() as f64;
    assert!(mean_queue < 0.5, "sparse traffic should not queue: {mean_queue}");

    let dense = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.01,
            cv: 0.5,
        },
        &pool,
        40,
        1,
    );
    let rec = simulate_trace(&cfg, &mut Fixed(2), &dense);
    let mean_queue_dense: f64 = rec
        .records()
        .iter()
        .map(|r| r.queue_delay())
        .sum::<f64>()
        / rec.len() as f64;
    assert!(
        mean_queue_dense > mean_queue * 10.0,
        "dense traffic must queue: {mean_queue_dense} vs {mean_queue}"
    );
}

/// The deterministic trace contract: identical seeds give identical
/// simulated latencies (experiments are exactly reproducible).
#[test]
fn simulation_is_deterministic() {
    let cfg = sim_cfg();
    let pool = vec![Prompt {
        ids: vec![1; 12],
        text: String::new(),
    }];
    let trace = Trace::generate(
        &TrafficPattern::fig6(),
        &pool,
        120,
        13,
    );
    let a = simulate_trace(&cfg, &mut Fixed(4), &trace);
    let b = simulate_trace(&cfg, &mut Fixed(4), &trace);
    let lat = |r: &specbatch::metrics::LatencyRecorder| {
        r.records().iter().map(|x| x.latency()).collect::<Vec<_>>()
    };
    assert_eq!(lat(&a), lat(&b));
}
