//! Latency-attribution acceptance tests (ISSUE PR 8 tentpole).
//!
//! Pins the three contracts of the causal-attribution layer:
//!
//! 1. **Tiling** — every non-shed `Finish` event carries a sealed
//!    [`Waterfall`] whose `total()` equals the recorder's measured
//!    end-to-end latency for that request *exactly* (the `other` bucket
//!    absorbs the residual, so the decomposition tiles by construction
//!    AND the named components account for what they claim).  Checked
//!    across seeds {2, 3, 4} on the static, continuous, and cluster DES
//!    drivers plus the threaded stub server in both scheduling modes.
//! 2. **Integer waste identity** — every traced round's slot split
//!    satisfies `committed + rejected + padding == width * (s + 1)`
//!    with no float in sight.
//! 3. **Flight-recorder invisibility** — attaching the always-on ring
//!    to a disabled handle changes no simulation output bit, and its
//!    dumps are parseable Chrome-trace + JSONL artifacts whose trigger
//!    causes reflect what happened (a shed storm arms `Shed`).

use std::collections::BTreeMap;

use specbatch::admission::{replicate_controllers, SloAware};
use specbatch::cluster::sim::simulate_trace_cluster_admission_tel;
use specbatch::cluster::{build_router, replicate_policies};
use specbatch::config::{AdmissionSpec, PolicySpec, RouterSpec};
use specbatch::kvcache::KvLayout;
use specbatch::metrics::RequestRecord;
use specbatch::policy::Fixed;
use specbatch::server::{run_experiment, Backend, SchedulingMode, ServerConfig};
use specbatch::simulator::{
    simulate_trace_admission_tel, simulate_trace_continuous_admission,
    simulate_trace_continuous_admission_tel,
};
use specbatch::telemetry::attrib::RoundWaste;
use specbatch::telemetry::flight::FlightRecorder;
use specbatch::telemetry::{EventKind, Telemetry, TelemetryMode};
use specbatch::testkit::harness::{
    const_prompt_pool, fig6_trace, paper_sim_config, slo_fig6_trace, stub_prompt_pool,
    stub_server_cfg, warm_model_based,
};
use specbatch::testkit::stub::StubSpec;
use specbatch::util::json::Json;

const EPS: f64 = 1e-9;

/// Check every non-shed Finish against its request record: the sealed
/// waterfall must tile the measured latency, its named components must
/// be non-negative, and the deferral count must agree.  `other` is
/// signed by design (it absorbs the residual); `max_other` bounds its
/// magnitude where the driver's clock discipline allows it.
fn assert_waterfalls_tile(
    tel: &Telemetry,
    records: &[RequestRecord],
    max_other: f64,
    what: &str,
) -> usize {
    let by_id: BTreeMap<u64, &RequestRecord> = records.iter().map(|r| (r.id, r)).collect();
    let mut checked = 0;
    for e in tel.events() {
        let EventKind::Finish {
            id,
            shed,
            waterfall,
            ..
        } = &e.kind
        else {
            continue;
        };
        if *shed {
            continue;
        }
        let wf = waterfall
            .as_ref()
            .unwrap_or_else(|| panic!("{what}: finish {id} has no waterfall"));
        let rec = by_id
            .get(id)
            .unwrap_or_else(|| panic!("{what}: finish {id} has no record"));
        assert!(
            (wf.total() - rec.latency()).abs() < EPS,
            "{what}: request {id}: waterfall totals {:.9}s but measured latency is {:.9}s",
            wf.total(),
            rec.latency()
        );
        for (name, v) in wf.components() {
            if name != "other" {
                assert!(
                    v >= -EPS,
                    "{what}: request {id}: component {name} is negative ({v:.9})"
                );
            }
        }
        assert!(
            wf.other.abs() <= max_other,
            "{what}: request {id}: unattributed residual {:.9}s exceeds {max_other:.9}s",
            wf.other
        );
        assert_eq!(
            wf.deferred_rounds, rec.deferred_rounds,
            "{what}: request {id}: deferral counts disagree"
        );
        checked += 1;
    }
    assert!(checked > 0, "{what}: no attributed finishes to check");
    checked
}

/// Every traced round must satisfy the integer slot identity.
fn assert_round_waste_tiles(tel: &Telemetry, what: &str) -> usize {
    let mut rounds = 0;
    for e in tel.events() {
        let EventKind::Round {
            live,
            width,
            s,
            drafted,
            committed,
            accepted,
            ..
        } = &e.kind
        else {
            continue;
        };
        let acc: usize = accepted.iter().map(|&a| a as usize).sum();
        assert!(*live <= *width, "{what}: live {live} > width {width}");
        assert!(
            *drafted <= live * s,
            "{what}: drafted {drafted} > live*s = {}",
            live * s
        );
        assert!(
            acc <= *drafted,
            "{what}: accepted {acc} > drafted = {drafted}"
        );
        let waste = RoundWaste::from_ragged_round(*width, *live, *s, *drafted, acc);
        assert!(
            waste.tiles(),
            "{what}: round at t={:.6}: {} + {} + {} != {} slots",
            e.t,
            waste.committed,
            waste.rejected,
            waste.padding,
            waste.slots()
        );
        // the event's committed count can fall short of accepted+live
        // only through max_new truncation — never exceed it
        assert!(
            *committed <= acc + live,
            "{what}: committed {committed} exceeds accepted+live = {}",
            acc + live
        );
        if *live > 0 {
            assert!(*committed >= 1, "{what}: live round committed nothing");
        }
        rounds += 1;
    }
    assert!(rounds > 0, "{what}: no rounds traced");
    rounds
}

// -------------------------------------------------------------- DES tiling

#[test]
fn des_waterfalls_tile_measured_latency_exactly() {
    for seed in [2u64, 3, 4] {
        let mut cfg = paper_sim_config(seed);
        cfg.max_new_tokens = 32;
        let trace = slo_fig6_trace(&const_prompt_pool(12), 150, seed, 0.1, 1.5, 2.0);

        // static: batch-to-completion epochs
        let tel = Telemetry::new(TelemetryMode::Trace);
        let rec = simulate_trace_admission_tel(
            &cfg,
            &mut Fixed(2),
            &mut SloAware::default(),
            &trace,
            &tel,
        );
        assert_waterfalls_tile(&tel, rec.records(), 1e-6, &format!("static seed {seed}"));
        assert_round_waste_tiles(&tel, &format!("static seed {seed}"));

        // continuous: iteration-level admission with a learning policy
        let tel = Telemetry::new(TelemetryMode::Trace);
        let mut policy = warm_model_based(&cfg, 30);
        let (rec, _) = simulate_trace_continuous_admission_tel(
            &cfg,
            &mut policy,
            &mut SloAware::default(),
            &trace,
            &tel,
        );
        assert_waterfalls_tile(&tel, rec.records(), 1e-6, &format!("continuous seed {seed}"));
        assert_round_waste_tiles(&tel, &format!("continuous seed {seed}"));

        // cluster: router + per-shard policies; route hops join the split
        let workers = 3;
        let tel = Telemetry::new(TelemetryMode::Trace);
        let mut policies =
            replicate_policies(&PolicySpec::Fixed(2), None, workers).expect("no LUT needed");
        let mut ctrls = replicate_controllers(AdmissionSpec::SloAware, workers);
        let mut router = build_router(RouterSpec::CostAware, seed);
        let out = simulate_trace_cluster_admission_tel(
            &cfg,
            &mut policies,
            &mut ctrls,
            router.as_mut(),
            &trace,
            &tel,
        );
        assert_waterfalls_tile(
            &tel,
            out.recorder.records(),
            1e-6,
            &format!("cluster seed {seed}"),
        );
        assert_round_waste_tiles(&tel, &format!("cluster seed {seed}"));
    }
}

// --------------------------------------------------------- threaded tiling

#[test]
fn threaded_server_waterfalls_tile_measured_latency() {
    for mode in [SchedulingMode::Static, SchedulingMode::Continuous] {
        let tel = Telemetry::new(TelemetryMode::Trace);
        let cfg = ServerConfig {
            telemetry: tel.clone(),
            ..stub_server_cfg(mode, KvLayout::Paged)
        };
        let trace = fig6_trace(&stub_prompt_pool(), 40, 7, 0.002);
        let out = run_experiment(
            Backend::Stub(StubSpec::default()),
            cfg,
            PolicySpec::Fixed(2),
            None,
            &trace,
        )
        .expect("stub experiment");
        // wall-clock drivers legitimately leave real unattributed time
        // (channel hops, scheduler jitter) — `other` is uncapped here;
        // the tiling identity itself stays exact
        let what = format!("threaded {mode:?}");
        assert_waterfalls_tile(&tel, out.recorder.records(), f64::INFINITY, &what);
        assert_round_waste_tiles(&tel, &what);
    }
}

// --------------------------------------------- flight recorder invisibility

#[test]
fn flight_recorder_presence_is_bit_invisible_to_the_des() {
    for seed in [2u64, 3, 4] {
        let mut cfg = paper_sim_config(seed);
        cfg.max_new_tokens = 32;
        let trace = slo_fig6_trace(&const_prompt_pool(12), 150, seed, 0.1, 1.5, 2.0);

        let mut p_off = warm_model_based(&cfg, 30);
        let (rec_off, rounds_off) = simulate_trace_continuous_admission(
            &cfg,
            &mut p_off,
            &mut SloAware::default(),
            &trace,
        );

        let prefix = std::env::temp_dir()
            .join(format!(
                "specbatch_flight_invis_{}_{seed}",
                std::process::id()
            ))
            .to_string_lossy()
            .into_owned();
        let flight = FlightRecorder::new(128, prefix);
        let tel = Telemetry::disabled().with_flight(flight.clone());
        let mut p_on = warm_model_based(&cfg, 30);
        let (rec_on, rounds_on) = simulate_trace_continuous_admission_tel(
            &cfg,
            &mut p_on,
            &mut SloAware::default(),
            &trace,
            &tel,
        );

        assert_eq!(
            rec_off.records(),
            rec_on.records(),
            "seed {seed}: flight recorder perturbed the records"
        );
        assert_eq!(
            rounds_off, rounds_on,
            "seed {seed}: flight recorder perturbed the round timeline"
        );
        assert!(
            flight.recorded() > 0,
            "seed {seed}: the ring saw nothing despite riding along"
        );
    }
}

// ------------------------------------------------------------- flight dumps

#[test]
fn shed_storm_arms_the_flight_recorder_and_dumps_parse() {
    let seed = 4u64;
    let mut cfg = paper_sim_config(seed);
    cfg.max_new_tokens = 32;
    // overload with tight deadlines: the SLO controller sheds (pinned by
    // the telemetry conservation test on this same trace shape)
    let trace = slo_fig6_trace(&const_prompt_pool(12), 300, seed, 0.1, 1.5, 2.0);

    let dir = std::env::temp_dir().join(format!("specbatch_flight_dump_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let prefix = dir.join("flight").to_string_lossy().into_owned();
    let flight = FlightRecorder::new(256, prefix);
    let tel = Telemetry::disabled().with_flight(flight.clone());
    let mut policy = warm_model_based(&cfg, 30);
    let (rec, _) = simulate_trace_continuous_admission_tel(
        &cfg,
        &mut policy,
        &mut SloAware::default(),
        &trace,
        &tel,
    );
    assert!(rec.shed_count() > 0, "overload trace should shed something");

    // the shed finishes armed the Shed trigger; poll() performs the dump
    assert!(flight.dump_pending(), "no trigger pending after a shed storm");
    let paths = flight.poll();
    assert_eq!(paths.len(), 2, "a dump is one Chrome trace + one JSONL");
    assert!(!flight.dump_pending(), "poll must clear the pending causes");

    let trace_doc = Json::parse_file(&paths[0]).expect("dump trace.json parses");
    let spans = trace_doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!spans.is_empty(), "dumped trace has no events");

    let body = std::fs::read_to_string(&paths[1]).expect("dump jsonl readable");
    let mut lines = body.lines();
    let header = Json::parse(lines.next().expect("jsonl has a header")).unwrap();
    assert_eq!(header.get("ev").unwrap().as_str().unwrap(), "flight_dump");
    let causes: Vec<String> = header
        .get("causes")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| c.as_str().unwrap().to_string())
        .collect();
    assert!(
        causes.iter().any(|c| c == "shed"),
        "dump causes {causes:?} miss the shed trigger"
    );
    let mut rounds = 0;
    for line in lines {
        let obj = Json::parse(line).expect("each dumped JSONL line parses");
        let ev = obj.get("ev").unwrap().as_str().unwrap();
        obj.get("t").unwrap().as_f64().unwrap();
        if ev == "round" {
            rounds += 1;
        }
    }
    assert!(rounds > 0, "dumped window contains no rounds");

    // a second dump gets a fresh sequence number, never clobbering
    let again = flight.dump_now().expect("manual dump");
    assert_ne!(again[0], paths[0], "dump files must not be overwritten");
    let _ = std::fs::remove_dir_all(&dir);
}
