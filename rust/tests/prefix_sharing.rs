//! Prefix-sharing acceptance tests (ISSUE PR 10 tentpole).
//!
//! Pins the four contracts of the prefix-sharing KV cache:
//!
//! 1. **Output transparency** — the cache is a pure prefill optimisation:
//!    a paged stub engine generates bit-identical tokens with the cache
//!    on and off, including on warm re-runs that map every shared block.
//! 2. **Leak invariant** — after any interleaving of admit / COW /
//!    retire / evict, releasing the row references and draining the trie
//!    returns the pool free list to capacity (randomised schedules over
//!    several seeds; a double release would trip the manager's refcount
//!    accounting long before the final audit).
//! 3. **DES payoff gate** — on the multi-tenant shared-prefix workload
//!    (seeds {2, 3, 4}) the admission-time mirror cuts charged prefill
//!    tokens by >= 10x and strictly improves mean TTFT vs the same trace
//!    served without sharing.
//! 4. **Off == baseline** — with `prefix_cache: false` the `_prefix` DES
//!    entry points return no stats and exactly the plain variants'
//!    output, so every pre-existing pinned-seed result is untouched.

use specbatch::admission::Fifo;
use specbatch::config::{AdmissionSpec, PolicySpec, RouterSpec};
use specbatch::admission::replicate_controllers;
use specbatch::cluster::sim::simulate_trace_cluster_admission_tel;
use specbatch::cluster::{build_router, replicate_policies};
use specbatch::engine::{Engine, EngineConfig};
use specbatch::kvcache::prefix::PrefixCache;
use specbatch::kvcache::{BlockManager, KvLayout, DEFAULT_BLOCK_SIZE};
use specbatch::policy::Fixed;
use specbatch::simulator::{
    simulate_trace_continuous_admission_tel, simulate_trace_continuous_admission_tel_prefix,
    AcceptanceProcess, CostModel, GpuProfile, ModelProfile, SimConfig,
};
use specbatch::telemetry::Telemetry;
use specbatch::testkit::stub::StubSpec;
use specbatch::traffic::{SharedPrefixSpec, Trace, TrafficPattern};
use specbatch::util::prng::Pcg64;

const BS: usize = DEFAULT_BLOCK_SIZE;

// ------------------------------------------------------ output transparency

fn stub_engine(prefix_cache: bool) -> Engine<'static> {
    Engine::stub(
        StubSpec {
            max_prompt: 64,
            ..StubSpec::default()
        },
        EngineConfig {
            kv_layout: KvLayout::Paged,
            prefix_cache,
            ..EngineConfig::default()
        },
    )
    .unwrap()
}

/// Four prompts sharing a two-block system prefix with distinct tails —
/// plus one disjoint prompt so misses run through the same epoch.
fn shared_prompts() -> Vec<Vec<i32>> {
    let system: Vec<i32> = (0..2 * BS as i32).map(|i| 5 + (i % 50)).collect();
    let mut prompts: Vec<Vec<i32>> = (0..4)
        .map(|t| {
            let mut p = system.clone();
            p.extend((0..6).map(|i| 7 + t * 9 + i));
            p
        })
        .collect();
    prompts.push((0..20).map(|i| 60 - (i % 40)).collect());
    prompts
}

#[test]
fn cache_on_generates_bit_identical_tokens() {
    let prompts = shared_prompts();
    let cold = stub_engine(false)
        .generate_batch(&prompts, 24, &mut Fixed(3))
        .unwrap();

    let mut e = stub_engine(true);
    assert!(e.prefix_enabled());
    let first = e.generate_batch(&prompts, 24, &mut Fixed(3)).unwrap();
    assert_eq!(cold.tokens, first.tokens, "cold pass must not change tokens");

    // warm pass: every shared block now maps; output still identical
    let second = e.generate_batch(&prompts, 24, &mut Fixed(3)).unwrap();
    assert_eq!(cold.tokens, second.tokens, "warm pass must not change tokens");
    let stats = e.prefix_stats().expect("enabled engine reports stats");
    assert!(stats.prefix_hits > 0, "warm pass should map shared blocks");
    assert!(stats.prefill_tokens_saved as usize >= 2 * BS);

    // leak audit: the trie's references are the only outstanding ones
    e.clear_prefix_cache();
    let kv = e.kv_block_stats().expect("paged engine");
    assert!(kv.is_leak_free(), "blocks leaked: {kv:?}");
}

#[test]
fn disabled_engine_reports_no_prefix_state() {
    let mut e = stub_engine(false);
    assert!(!e.prefix_enabled());
    assert!(e.prefix_stats().is_none());
    e.generate_batch(&shared_prompts(), 8, &mut Fixed(2)).unwrap();
    assert!(e.prefix_stats().is_none());
}

// ----------------------------------------------------------- leak invariant

/// One randomised admit/COW/retire/evict schedule against the real
/// cache + pool pair, with row-held references tracked on the side the
/// way an engine block table would hold them.
fn run_schedule(seed: u64, cap: usize, ops: usize) {
    let mut mgr = BlockManager::new(cap, BS);
    let mut cache = PrefixCache::new(BS);
    let mut rng = Pcg64::new(seed);
    let mut rows: Vec<Vec<u32>> = Vec::new();

    // two tenant groups x four templates: 40-token prompts, the first
    // 32 shared within a group
    let prompts: Vec<Vec<i32>> = (0..8)
        .map(|t| {
            let mut p: Vec<i32> = (0..2 * BS as i32).map(|i| 5 + (t % 2) * 31 + i).collect();
            p.extend((0..8).map(|i| 300 + t * 11 + i));
            p
        })
        .collect();

    for _ in 0..ops {
        match rng.next_u64() % 10 {
            // admit: lookup, COW a mid-block tail, prefill the suffix,
            // register the chain (the engine's exact choreography)
            0..=5 => {
                let p = &prompts[(rng.next_u64() as usize) % prompts.len()];
                let mappable = &p[..p.len() - 1];
                let (mut owned, covered) = match cache.lookup(mappable, &mut mgr) {
                    Some(m) => (m.blocks, m.tokens),
                    None => (Vec::new(), 0),
                };
                let mut aborted = false;
                if covered % BS != 0 {
                    // shared partially filled tail is about to be written
                    let shared = owned.pop().expect("partial coverage has a tail");
                    match cache.cow_tail(&mut mgr, shared) {
                        Ok(fresh) => owned.push(fresh),
                        Err(_) => aborted = true,
                    }
                }
                let total = p.len().div_ceil(BS);
                while !aborted && owned.len() < total {
                    match mgr.alloc() {
                        Ok(id) => owned.push(id),
                        Err(_) => {
                            if !cache.evict_lru(&mut mgr) {
                                aborted = true;
                            }
                        }
                    }
                }
                if aborted {
                    for b in owned.drain(..) {
                        mgr.release(b);
                    }
                    continue;
                }
                cache.insert(p, &owned, &mut mgr);
                rows.push(owned);
            }
            // retire a random row
            6..=7 => {
                if !rows.is_empty() {
                    let i = (rng.next_u64() as usize) % rows.len();
                    for b in rows.swap_remove(i) {
                        mgr.release(b);
                    }
                }
            }
            // spontaneous LRU eviction
            8 => {
                cache.evict_lru(&mut mgr);
            }
            // pressure: demand some free headroom
            _ => {
                cache.evict_until_free(&mut mgr, 1 + (rng.next_u64() as usize) % 4);
            }
        }
        // running consistency: the pool's books must always balance
        let s = mgr.stats();
        assert_eq!(s.in_use + s.free, s.capacity, "seed {seed}: {s:?}");
    }

    for row in rows.drain(..) {
        for b in row {
            mgr.release(b);
        }
    }
    cache.evict_all(&mut mgr);
    assert_eq!(cache.cached_blocks(), 0, "seed {seed}: trie not drained");
    assert_eq!(
        mgr.free_blocks(),
        cap,
        "seed {seed}: free list short of capacity"
    );
    let s = mgr.stats();
    assert!(s.is_leak_free(), "seed {seed}: {s:?}");
}

#[test]
fn random_admit_cow_retire_evict_schedules_are_leak_free() {
    for seed in 0..12u64 {
        // tight pool: evictions and allocation pressure both fire
        run_schedule(seed, 24, 300);
        // roomy pool: the LRU reserve grows and drains via evict_all
        run_schedule(seed + 100, 96, 300);
    }
}

// ---------------------------------------------------------- DES payoff gate

fn payoff_cfg(seed: u64, prefix_cache: bool) -> SimConfig {
    SimConfig {
        llm: CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        ssm: CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        acceptance: AcceptanceProcess::paper(),
        class_acceptance: Default::default(),
        drift: None,
        max_batch: 16,
        max_new_tokens: 32,
        host_overhead: 0.2e-3,
        kv_layout: KvLayout::Paged,
        kv_block: DEFAULT_BLOCK_SIZE,
        prefix_cache,
        seed,
    }
}

fn shared_trace(seed: u64, n: usize) -> Trace {
    let pool = vec![specbatch::dataset::Prompt {
        ids: vec![1; 8],
        text: String::new(),
    }];
    let pattern = TrafficPattern::Stationary {
        interval: 0.05,
        cv: 1.0,
    };
    Trace::generate(&pattern, &pool, n, seed)
        .with_shared_prefix(&SharedPrefixSpec::default(), seed)
}

#[test]
fn shared_prefix_traffic_cuts_prefill_10x_and_improves_ttft() {
    for seed in [2u64, 3, 4] {
        // enough requests that the 16 cold (tenant, template) misses are
        // amortised well past the 10x bar (~200 would only reach ~9x)
        let trace = shared_trace(seed, 600);
        let total_plen: usize = trace.items.iter().map(|it| it.prompt.ids.len()).sum();

        let (rec_off, _, stats_off) = simulate_trace_continuous_admission_tel_prefix(
            &payoff_cfg(seed, false),
            &mut Fixed(2),
            &mut Fifo,
            &trace,
            &Telemetry::disabled(),
        );
        assert!(stats_off.is_none(), "cache off must not build an index");

        let (rec_on, _, stats_on) = simulate_trace_continuous_admission_tel_prefix(
            &payoff_cfg(seed, true),
            &mut Fixed(2),
            &mut Fifo,
            &trace,
            &Telemetry::disabled(),
        );
        let stats = stats_on.expect("cache on returns stats");

        let charged_off = total_plen as f64;
        let charged_on = charged_off - stats.prefill_tokens_saved as f64;
        assert!(charged_on > 0.0, "seed {seed}: over-saving is impossible");
        let cut = charged_off / charged_on;
        assert!(
            cut >= 10.0,
            "seed {seed}: prefill cut {cut:.2}x below the 10x bar \
             ({charged_off} -> {charged_on} tokens)"
        );
        assert!(stats.hit_rate() > 0.8, "seed {seed}: {stats:?}");

        let (ttft_off, ttft_on) = (rec_off.mean_ttft(), rec_on.mean_ttft());
        assert!(
            ttft_on < ttft_off,
            "seed {seed}: TTFT must strictly improve ({ttft_on:.4}s vs {ttft_off:.4}s)"
        );
        // sharing is a prefill discount; batch regrouping at the earlier
        // round boundaries allows tiny per-request wiggle, not regressions
        assert!(rec_on.summary().mean <= rec_off.summary().mean * 1.05);
        assert_eq!(rec_on.len(), rec_off.len());
    }
}

#[test]
fn cluster_shards_roll_their_prefix_stats_into_the_report() {
    let seed = 3u64;
    let trace = shared_trace(seed, 300);
    let workers = 2;
    let mut policies =
        replicate_policies(&PolicySpec::Fixed(2), None, workers).expect("no LUT needed");
    let mut ctrls = replicate_controllers(AdmissionSpec::Fifo, workers);
    let mut router = build_router(RouterSpec::RoundRobin, seed);
    let report = simulate_trace_cluster_admission_tel(
        &payoff_cfg(seed, true),
        &mut policies,
        &mut ctrls,
        router.as_mut(),
        &trace,
        &Telemetry::disabled(),
    );
    let stats = report.prefix.expect("per-shard caches merge into one line");
    assert!(stats.lookups >= trace.len() as u64);
    assert!(stats.prefix_hits > 0, "{stats:?}");
    assert!(stats.prefill_tokens_saved > 0, "{stats:?}");

    // cache off: no stats object at all
    let mut policies =
        replicate_policies(&PolicySpec::Fixed(2), None, workers).expect("no LUT needed");
    let mut ctrls = replicate_controllers(AdmissionSpec::Fifo, workers);
    let mut router = build_router(RouterSpec::RoundRobin, seed);
    let report_off = simulate_trace_cluster_admission_tel(
        &payoff_cfg(seed, false),
        &mut policies,
        &mut ctrls,
        router.as_mut(),
        &trace,
        &Telemetry::disabled(),
    );
    assert!(report_off.prefix.is_none());
}

// --------------------------------------------------------- off == baseline

#[test]
fn prefix_entry_points_with_cache_off_match_the_plain_variants() {
    for seed in [2u64, 3, 4] {
        let cfg = payoff_cfg(seed, false);
        let trace = shared_trace(seed, 150);
        let (rec_a, rounds_a) = simulate_trace_continuous_admission_tel(
            &cfg,
            &mut Fixed(2),
            &mut Fifo,
            &trace,
            &Telemetry::disabled(),
        );
        let (rec_b, rounds_b, stats) = simulate_trace_continuous_admission_tel_prefix(
            &cfg,
            &mut Fixed(2),
            &mut Fifo,
            &trace,
            &Telemetry::disabled(),
        );
        assert!(stats.is_none());
        assert_eq!(rounds_a.len(), rounds_b.len());
        assert_eq!(rec_a.len(), rec_b.len());
        for (a, b) in rec_a.records().iter().zip(rec_b.records()) {
            assert_eq!(a.latency().to_bits(), b.latency().to_bits(), "seed {seed}");
        }
    }
}

// ----------------------------------------------------- shared-prefix trace

#[test]
fn with_shared_prefix_is_deterministic_and_shaped_as_specified() {
    let spec = SharedPrefixSpec::default();
    let a = shared_trace(7, 100);
    let b = shared_trace(7, 100);
    for (x, y) in a.items.iter().zip(&b.items) {
        assert_eq!(x.prompt.ids, y.prompt.ids, "same seed, same prompts");
    }
    for it in &a.items {
        assert_eq!(it.prompt.ids.len(), spec.prompt_len());
    }
    // distinct user tails keep prompts from being outright duplicates
    // while the shared span stays block-aligned cacheable
    assert!(spec.shared_len() >= 2 * DEFAULT_BLOCK_SIZE);
}
