//! Fig. 6 — latency timeline under alternating traffic: the client
//! switches between *intense* (0.2 s mean interval) and *sparse* (1.0 s)
//! every 50 seconds (CV = 1); each point is the mean latency of a group
//! of 40 consecutive requests.
//!
//! Shape to reproduce: fixed-2 wins in the intense phases, fixed-4 wins
//! in the sparse phases, and adaptive tracks whichever is better (paper:
//! adaptive improves 9% over fixed-2 and 14% over fixed-4 on average).
//!
//! Runs at paper scale on the calibrated simulator with one shared trace
//! for all four policies.  Output: results/fig6_timeline.csv.

#[allow(dead_code)]
mod common;

use specbatch::dataset::Prompt;
use specbatch::metrics::timeline_groups;
use specbatch::simulator::{
    comparison_policies, simulate_trace, simulated_lut, AcceptanceProcess, CostModel,
    GpuProfile, ModelProfile, SimConfig,
};
use specbatch::traffic::{Trace, TrafficPattern};
use specbatch::util::csv::{f, Csv};
use specbatch::util::json::Json;

fn main() {
    let cfg = SimConfig {
        llm: CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        ssm: CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        acceptance: AcceptanceProcess::paper(),
        class_acceptance: Default::default(),
        drift: None,
        max_batch: 16,
        max_new_tokens: 128,
        host_overhead: 0.2e-3,
        kv_layout: specbatch::kvcache::KvLayout::Paged,
        kv_block: specbatch::kvcache::DEFAULT_BLOCK_SIZE,
        prefix_cache: false,
        seed: 6,
    };
    let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
    println!("simulated LUT: {}", lut.to_json().compact());
    let mut policies = comparison_policies(lut);

    let n_requests = if common::is_quick() { 300 } else { 1000 };
    let pool: Vec<Prompt> = (4..=24)
        .map(|n| Prompt {
            ids: vec![1; n],
            text: String::new(),
        })
        .collect();
    // one shared alternating trace (Fig. 6 methodology)
    let trace = Trace::generate(&TrafficPattern::fig6(), &pool, n_requests, 66);
    println!(
        "trace: {} requests over {:.0}s (phases flip every 50s)",
        trace.len(),
        trace.span()
    );

    let mut csv = Csv::new(&["policy", "group_t_start_s", "group_mean_latency_s", "n"]);
    let mut means = Vec::new();
    let mut phase_means: Vec<(String, f64, f64)> = Vec::new();
    let mut adaptive_rec = None;
    for (name, policy) in policies.iter_mut() {
        let rec = simulate_trace(&cfg, policy.as_mut(), &trace);
        let groups = timeline_groups(rec.records(), 40);
        for g in &groups {
            csv.row(&[
                name.clone(),
                f(g.t_start),
                f(g.mean_latency),
                g.n.to_string(),
            ]);
        }
        let mean = rec.summary().mean;
        means.push((name.clone(), mean));
        // split by phase for the structural check
        let lat_in = |lo: f64, hi: f64| {
            let xs: Vec<f64> = rec
                .records()
                .iter()
                .filter(|r| r.sent_at >= lo && r.sent_at < hi)
                .map(|r| r.latency())
                .collect();
            if xs.is_empty() {
                f64::NAN
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        // phases 2 and 3 (100-150 intense, 150-200 sparse) are steady-state
        phase_means.push((name.clone(), lat_in(100.0, 150.0), lat_in(150.0, 200.0)));
        if name == "adaptive" {
            adaptive_rec = Some(rec);
        }
    }

    // the CI trajectory point for this figure: the adaptive series
    if let Some(rec) = &adaptive_rec {
        common::emit_bench(
            "fig6_timeline",
            rec,
            &[],
            Json::obj(vec![
                ("bench", Json::Str("fig6_timeline".into())),
                ("policy", Json::Str("adaptive".into())),
                ("requests", Json::Num(n_requests as f64)),
                ("trace_seed", Json::Num(66.0)),
                ("scale", Json::Str(common::scale())),
            ]),
        );
    }

    let rows: Vec<Vec<String>> = phase_means
        .iter()
        .zip(&means)
        .map(|((name, intense, sparse), (_, overall))| {
            vec![
                name.clone(),
                format!("{intense:.2}"),
                format!("{sparse:.2}"),
                format!("{overall:.2}"),
            ]
        })
        .collect();
    common::print_table(
        &[
            "policy".into(),
            "intense phase (s)".into(),
            "sparse phase (s)".into(),
            "overall (s)".into(),
        ],
        &rows,
    );

    let get = |n: &str| means.iter().find(|(m, _)| m == n).unwrap().1;
    let adaptive = get("adaptive");
    println!(
        "adaptive vs fixed-2: {:+.1}%  vs fixed-4: {:+.1}%  (paper: 9% and 14%)",
        (1.0 - adaptive / get("fixed-2")) * 100.0,
        (1.0 - adaptive / get("fixed-4")) * 100.0,
    );

    // shape assertions
    let pm = |n: &str| phase_means.iter().find(|(m, _, _)| m == n).unwrap();
    let f2 = pm("fixed-2");
    let f4 = pm("fixed-4");
    assert!(
        f2.1 < f4.1,
        "fixed-2 should win the intense phase ({} vs {})",
        f2.1,
        f4.1
    );
    assert!(
        f4.2 < f2.2,
        "fixed-4 should win the sparse phase ({} vs {})",
        f4.2,
        f2.2
    );
    assert!(
        adaptive <= get("fixed-2") * 1.02 && adaptive <= get("fixed-4") * 1.02,
        "adaptive should match or beat both fixed schemes"
    );
    println!("shape verified: fixed-2 wins intense ✓  fixed-4 wins sparse ✓  adaptive ≤ both ✓");

    csv.write_file(common::results_path("fig6_timeline.csv"))
        .unwrap();
    println!("-> results/fig6_timeline.csv");
}
