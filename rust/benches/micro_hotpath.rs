//! Micro-benchmarks of the L3 hot path (the §Perf workhorse).
//!
//! Times each stage of a serving round in isolation on the real runtime:
//! host staging, SSM speculate, LLM verify (per s), acceptance logic, and
//! the end-to-end round; prints the engine stopwatch breakdown.  Both
//! build flavors additionally sweep an end-to-end **rounds/s** grid on
//! the stub backend — the zero-allocation hot-path yardstick CI's
//! bench-regress step diffs against the committed baseline.  Run
//! before/after each optimization and record deltas in EXPERIMENTS.md
//! §Perf.

#[allow(dead_code)]
mod common;

use std::time::Instant;

use specbatch::engine::acceptance::accept_batch;
use specbatch::engine::{Engine, EngineConfig};
#[cfg(feature = "pjrt")]
use specbatch::model::Model;
use specbatch::policy::Fixed;
use specbatch::testkit::stub::StubSpec;
use specbatch::util::csv::{f, Csv};
use specbatch::util::json::Json;
use specbatch::util::prng::Pcg64;

/// Time the pure-host acceptance kernel (both build flavors run this).
fn bench_acceptance(csv: &mut Csv) -> f64 {
    let b = 16;
    let s = 4;
    let mut rng = Pcg64::new(1);
    // bulk-fill the token material (same draws as the sequential loop)
    let mut raw = vec![0u32; b * s + b * (s + 1)];
    rng.fill_below(512, &mut raw);
    let draft: Vec<i32> = raw[..b * s].iter().map(|&v| v as i32).collect();
    let pred: Vec<i32> = raw[b * s..].iter().map(|&v| v as i32).collect();
    let t0 = Instant::now();
    let iters = 100_000;
    for _ in 0..iters {
        std::hint::black_box(accept_batch(
            std::hint::black_box(&draft),
            std::hint::black_box(&pred),
            b,
            s,
        ));
    }
    let us = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;
    println!("acceptance(b=16,s=4): {us:.3} µs");
    csv.row(&["acceptance".into(), b.to_string(), s.to_string(), f(us)]);
    us
}

/// End-to-end rounds/s on the stub backend: steady-state `decode_round`
/// over a full (batch × spec-len) grid, no admission or retirement, so
/// the number isolates the SoA/arena decode loop itself.  The headline
/// cell is `rps_b32_s4`.
fn bench_rounds_per_sec(csv: &mut Csv) -> Vec<(String, Json)> {
    let rounds = if common::is_quick() { 30 } else { 200 };
    let warmup = 3;
    let mut metrics = Vec::new();
    for &b in &[1usize, 8, 16, 32] {
        for &s in &[0usize, 2, 4, 6] {
            let spec = StubSpec {
                vocab: 512,
                max_seq: 2048,
                batch_buckets: vec![1, 8, 16, 32],
                ..StubSpec::default()
            };
            let mut engine =
                Engine::stub(spec, EngineConfig::default()).expect("stub engine");
            let mut policy = Fixed(s);
            let mut rng = Pcg64::new(0x517e + b as u64);
            let prompts: Vec<Vec<i32>> = (0..b)
                .map(|_| (0..8).map(|_| 4 + rng.next_below(500) as i32).collect())
                .collect();
            // rows must outlive the timed window: commit ceiling past it
            let max_new = (warmup + rounds) * (s + 1) + 4;
            let mut st = engine
                .prefill_rows(&prompts, b, s > 0, max_new)
                .expect("prefill");
            for _ in 0..warmup {
                engine.decode_round(&mut st, &mut policy).expect("warmup");
            }
            let t0 = Instant::now();
            for _ in 0..rounds {
                engine.decode_round(&mut st, &mut policy).expect("round");
            }
            let rps = rounds as f64 / t0.elapsed().as_secs_f64();
            println!("rounds_per_sec(b={b},s={s}): {rps:.0}");
            csv.row(&[
                "rounds_per_sec".into(),
                b.to_string(),
                s.to_string(),
                f(rps),
            ]);
            metrics.push((format!("rps_b{b}_s{s}"), Json::Num(rps)));
        }
    }
    metrics
}

/// Without the PJRT runtime the host-side sections and the stub-backend
/// rounds/s grid run.
#[cfg(not(feature = "pjrt"))]
fn main() {
    let mut csv = Csv::new(&["section", "batch", "s", "mean_us"]);
    let acc_us = bench_acceptance(&mut csv);
    let rps = bench_rounds_per_sec(&mut csv);
    csv.write_file(common::results_path("micro_hotpath.csv"))
        .unwrap();
    common::skip_real("device-step micro-benchmarks");
    println!("-> results/micro_hotpath.csv (host sections only)");
    let mut metrics = vec![("acceptance_us".to_string(), Json::Num(acc_us))];
    metrics.extend(rps);
    common::emit_bench_custom(
        "micro_hotpath",
        Json::Obj(metrics.into_iter().collect()),
        Json::obj(vec![
            ("bench", Json::Str("micro_hotpath".into())),
            ("sections", Json::Str("host-only".into())),
            ("scale", Json::Str(common::scale())),
        ]),
    );
}

#[cfg(feature = "pjrt")]
fn main() {
    let rt = common::load_runtime_or_exit();
    let dataset = rt.dataset().expect("dataset");
    let reps = if common::is_quick() { 10 } else { 50 };
    let mut csv = Csv::new(&["section", "batch", "s", "mean_us"]);

    // --- acceptance logic (pure host) ---
    let acc_us = bench_acceptance(&mut csv);

    // --- stub-backend rounds/s grid (host-side hot path) ---
    let rps = bench_rounds_per_sec(&mut csv);

    // --- single verify / speculate steps ---
    let llm = Model::new(&rt, "llm").expect("llm");
    let ssm = Model::new(&rt, "ssm").expect("ssm");
    for &b in &[1usize, 4, 8] {
        if !rt.manifest.batch_buckets.contains(&b) {
            continue;
        }
        for &s in &[1usize, 3] {
            if rt.manifest.max_spec_len(b) < s {
                continue;
            }
            // LLM verify
            let mut kv = llm.new_kv(b).expect("kv");
            let tokens = vec![5i32; b * llm.spec.max_prompt];
            let plens = vec![8i32; b];
            llm.prefill(&tokens, &plens, b, &mut kv).expect("prefill");
            let feed = vec![7i32; b * (s + 1)];
            let clamp = vec![9u32; b];
            llm.verify(&feed, s, b, &mut kv).expect("warmup");
            kv.clamp_to(&clamp);
            let t0 = Instant::now();
            for _ in 0..reps {
                llm.verify(&feed, s, b, &mut kv).expect("verify");
                kv.clamp_to(&clamp);
            }
            let us = t0.elapsed().as_secs_f64() / reps as f64 * 1e6;
            println!("llm_verify(b={b},s={s}): {:.1} µs", us);
            csv.row(&["llm_verify".into(), b.to_string(), s.to_string(), f(us)]);

            // SSM speculate
            let mut kv = ssm.new_kv(b).expect("kv");
            ssm.prefill(&tokens, &plens, b, &mut kv).expect("prefill");
            let delta = vec![7i32; b * 2];
            let dlens = vec![1i32; b];
            ssm.speculate(&delta, &dlens, s, b, &mut kv).expect("warmup");
            kv.clamp_to(&clamp);
            let t0 = Instant::now();
            for _ in 0..reps {
                ssm.speculate(&delta, &dlens, s, b, &mut kv).expect("spec");
                kv.clamp_to(&clamp);
            }
            let us = t0.elapsed().as_secs_f64() / reps as f64 * 1e6;
            println!("ssm_speculate(b={b},s={s}): {:.1} µs", us);
            csv.row(&["ssm_speculate".into(), b.to_string(), s.to_string(), f(us)]);
        }
    }

    // --- end-to-end round breakdown via the engine stopwatch ---
    let e2e_us;
    {
        let mut engine = Engine::new(&rt, EngineConfig::default()).expect("engine");
        let mut rng = Pcg64::new(9);
        let prompts: Vec<Vec<i32>> = dataset
            .sample_eval(&mut rng, 4)
            .into_iter()
            .map(|p| p.ids)
            .collect();
        let tokens = if common::is_quick() { 16 } else { 48 };
        let out = engine
            .generate_batch(&prompts, tokens, &mut Fixed(3))
            .expect("gen");
        println!(
            "\nend-to-end b=4 s=3: {:.2} ms/token, {} rounds, {:.2} accepted/round",
            out.stats.per_token_latency() * 1e3,
            out.stats.rounds,
            out.stats.mean_accepted()
        );
        println!("\nengine stopwatch breakdown:\n{}", engine.stopwatch.report());
        e2e_us = out.stats.per_token_latency() * 1e6;
        csv.row(&["e2e_per_token".into(), "4".into(), "3".into(), f(e2e_us)]);
    }

    csv.write_file(common::results_path("micro_hotpath.csv"))
        .unwrap();
    println!("-> results/micro_hotpath.csv");
    let mut metrics = vec![
        ("acceptance_us".to_string(), Json::Num(acc_us)),
        ("e2e_us_per_token".to_string(), Json::Num(e2e_us)),
    ];
    metrics.extend(rps);
    common::emit_bench_custom(
        "micro_hotpath",
        Json::Obj(metrics.into_iter().collect()),
        Json::obj(vec![
            ("bench", Json::Str("micro_hotpath".into())),
            ("sections", Json::Str("full".into())),
            ("reps", Json::Num(reps as f64)),
            ("scale", Json::Str(common::scale())),
        ]),
    );
}
