//! Fig. 3 — the LLM verify-step cost t_L(b, s) as a function of s for
//! different batch sizes; approximately flat (memory-bound) until the
//! roofline knee, then growing (the paper approximates it as linear
//! α_b·s + β with α_b increasing in b).
//!
//! Two reproductions:
//!
//! 1. **Simulator** (paper scale: OPT-6.7B on RTX 3090, s up to 64):
//!    shows the knee at b·(s+1) ≈ crossover — b=1 jumps near s≈64, b=8
//!    near s≈8, exactly the paper's observation.
//! 2. **Real execution**: wall-time of `Model::verify` on the tiny LLM
//!    per (bucket, s), plus the fitted α_b, β per bucket.
//!
//! Output: results/fig3_sim.csv, results/fig3_real.csv, fitted
//! results/fig3_alpha.csv.

#[allow(dead_code)]
mod common;

use specbatch::simulator::{CostModel, GpuProfile, ModelProfile};
use specbatch::util::csv::{f, Csv};
use specbatch::util::json::Json;

fn main() {
    sim_curves();
    real_curves();
}

#[cfg(not(feature = "pjrt"))]
fn real_curves() {
    common::skip_real("Fig. 3 real-execution verify-latency curves");
}

fn sim_curves() {
    println!("== Fig. 3 (simulator: OPT-6.7B on RTX 3090) ==");
    let cm = CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090);
    let batches = [1usize, 2, 4, 8, 16, 32];
    let slens: Vec<usize> = vec![0, 1, 2, 4, 8, 16, 32, 48, 64];
    let mut csv = Csv::new(&["batch", "s", "t_L_ms"]);
    let mut rows = Vec::new();
    for &b in &batches {
        let mut cells = vec![format!("b={b}")];
        for &s in &slens {
            let t = cm.t_verify(b, s, 128) * 1e3;
            csv.row(&[b.to_string(), s.to_string(), f(t)]);
            cells.push(format!("{t:.1}"));
        }
        rows.push(cells);
    }
    let mut header = vec!["batch".to_string()];
    header.extend(slens.iter().map(|s| format!("s={s}")));
    common::print_table(&header, &rows);
    println!(
        "(roofline knee at b·(s+1) ≈ {:.0} tokens — cf. paper: b=1 jumps at s≈64, b=8 at s≈8)",
        GpuProfile::RTX3090.crossover_tokens()
    );
    csv.write_file(common::results_path("fig3_sim.csv")).unwrap();
    println!("-> results/fig3_sim.csv\n");

    // memory-bound flatness at b=1 vs compute-bound growth at b=32
    common::emit_bench_custom(
        "fig3_verify_latency",
        Json::obj(vec![
            (
                "crossover_tokens",
                Json::Num(GpuProfile::RTX3090.crossover_tokens()),
            ),
            (
                "b1_s8_over_s0",
                Json::Num(cm.t_verify(1, 8, 128) / cm.t_verify(1, 0, 128)),
            ),
            (
                "b32_s64_over_s0",
                Json::Num(cm.t_verify(32, 64, 128) / cm.t_verify(32, 0, 128)),
            ),
        ]),
        Json::obj(vec![
            ("bench", Json::Str("fig3_verify_latency".into())),
            ("model", Json::Str("opt-6.7b".into())),
            ("gpu", Json::Str("rtx3090".into())),
            ("scale", Json::Str(common::scale())),
        ]),
    );
}

#[cfg(feature = "pjrt")]
fn real_curves() {
    use std::time::Instant;

    use specbatch::model::Model;
    use specbatch::util::stats::linear_fit;

    println!("== Fig. 3 (real execution: tiny LLM verify step on CPU PJRT) ==");
    let rt = common::load_runtime_or_exit();
    let llm = Model::new(&rt, "llm").expect("model");
    let buckets: Vec<usize> = if common::is_quick() {
        vec![1, 2, 4]
    } else {
        rt.manifest.batch_buckets.clone()
    };
    let slens: Vec<usize> = rt.manifest.verify_lengths.clone();
    let reps = if common::is_quick() { 5 } else { 20 };

    let mut csv = Csv::new(&["batch", "s", "t_L_ms"]);
    let mut alpha_csv = Csv::new(&["batch", "alpha_ms_per_s", "beta_ms", "r2"]);
    let mut rows = Vec::new();
    for &b in &buckets {
        let mut cells = vec![format!("b={b}")];
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for &s in &slens {
            if s > 0 && rt.manifest.max_spec_len(b) < s {
                cells.push("-".into());
                continue;
            }
            // fresh KV + prefill context so the verify step is realistic
            let mut kv = llm.new_kv(b).expect("kv");
            let p = llm.spec.max_prompt;
            let tokens = vec![5i32; b * p];
            let plens = vec![8i32; b];
            llm.prefill(&tokens, &plens, b, &mut kv).expect("prefill");
            // warmup (compile + cache touch)
            let feed = vec![7i32; b * (s + 1)];
            llm.verify(&feed, s, b, &mut kv).expect("verify");
            let clamp: Vec<u32> = vec![9; b];
            kv.clamp_to(&clamp);
            // timed reps (re-clamping keeps state bounded)
            let t0 = Instant::now();
            for _ in 0..reps {
                llm.verify(&feed, s, b, &mut kv).expect("verify");
                kv.clamp_to(&clamp);
            }
            let ms = t0.elapsed().as_secs_f64() / reps as f64 * 1e3;
            csv.row(&[b.to_string(), s.to_string(), f(ms)]);
            cells.push(format!("{ms:.2}"));
            xs.push(s as f64);
            ys.push(ms);
        }
        if xs.len() >= 2 {
            let (alpha, beta, r2) = linear_fit(&xs, &ys);
            alpha_csv.row(&[b.to_string(), f(alpha), f(beta), f(r2)]);
            println!("b={b}: t_L(s) ≈ {alpha:.3}·s + {beta:.3} ms (r²={r2:.3})");
        }
        rows.push(cells);
    }
    let mut header = vec!["batch".to_string()];
    header.extend(slens.iter().map(|s| format!("s={s}")));
    common::print_table(&header, &rows);
    csv.write_file(common::results_path("fig3_real.csv")).unwrap();
    alpha_csv
        .write_file(common::results_path("fig3_alpha.csv"))
        .unwrap();
    println!("-> results/fig3_real.csv, results/fig3_alpha.csv");
}
