//! Fig. 2 — how l(s) scales with s, approximated by a sublinear power
//! function c·s^γ (the paper measures 0.9·s^0.548 for OPT-125M drafting
//! OPT-6.7B).
//!
//! Reproduction: run the *real* trained tiny pair with s = 8 speculation,
//! record per-round accepted counts, apply the Eq. 4 estimator, and fit
//! the power law.  Our draft/target pair is much smaller than the
//! paper's, so c and γ differ, but the curve must be (a) non-decreasing,
//! (b) sublinear (γ < 1), (c) well fit by a power law — those are the
//! claims the analytical model rests on.
//!
//! Output: results/fig2_acceptance.csv (s, l_measured, l_fit).

#[allow(dead_code)]
mod common;

#[cfg(feature = "pjrt")]
use specbatch::analytic::{l_of_s_estimate, AcceptanceModel};
#[cfg(feature = "pjrt")]
use specbatch::engine::{Engine, EngineConfig};
#[cfg(feature = "pjrt")]
use specbatch::policy::Fixed;
#[cfg(feature = "pjrt")]
use specbatch::util::csv::{f, Csv};
use specbatch::util::json::Json;
#[cfg(feature = "pjrt")]
use specbatch::util::prng::Pcg64;

#[cfg(not(feature = "pjrt"))]
fn main() {
    common::skip_real("Fig. 2 acceptance-curve measurement");
    // keep the CI artifact set complete even when the measurement is
    // impossible in this build
    common::emit_bench_custom(
        "fig2_acceptance",
        Json::obj(vec![("skipped_no_pjrt", Json::Bool(true))]),
        Json::obj(vec![
            ("bench", Json::Str("fig2_acceptance".into())),
            ("scale", Json::Str(common::scale())),
        ]),
    );
}

#[cfg(feature = "pjrt")]
fn main() {
    let rt = common::load_runtime_or_exit();
    let dataset = rt.dataset().expect("dataset");
    let s_probe = 8usize;
    // s=8 executables exist for buckets 1 and 4 (extra_verify in the
    // artifact profile); use 4 for more samples per round
    let bucket = if rt.manifest.has_exe(
        "llm",
        specbatch::runtime::ExeKind::Verify,
        4,
        s_probe,
    ) {
        4
    } else {
        1
    };
    let mut engine = Engine::new(
        &rt,
        EngineConfig {
            record_acceptance: true,
            stop_at_eos: false,
            ..EngineConfig::default()
        },
    )
    .expect("engine");

    let n_batches = if common::is_quick() { 2 } else { 12 };
    let tokens = if common::is_quick() { 24 } else { 48 };
    let mut rng = Pcg64::new(0xF16_2);
    let mut samples: Vec<u32> = Vec::new();
    for _ in 0..n_batches {
        let prompts: Vec<Vec<i32>> = dataset
            .sample_eval(&mut rng, bucket)
            .into_iter()
            .map(|p| p.ids)
            .collect();
        let out = engine
            .generate_batch(&prompts, tokens, &mut Fixed(s_probe))
            .expect("gen");
        samples.extend(&out.stats.accept_samples);
    }
    println!(
        "collected {} accepted-count samples (bucket {bucket}, s = {s_probe})",
        samples.len()
    );

    let l = l_of_s_estimate(&samples, s_probe);
    let fit = AcceptanceModel::fit(&l).expect("fit");
    println!(
        "fit: l(s) ≈ {:.3}·s^{:.3}   (r² = {:.4}; paper: 0.9·s^0.548)",
        fit.c, fit.gamma, fit.r2
    );

    let mut csv = Csv::new(&["s", "l_measured", "l_fit"]);
    let mut rows = Vec::new();
    for (i, &li) in l.iter().enumerate() {
        let s = i + 1;
        let lf = fit.l(s as f64);
        csv.row(&[s.to_string(), f(li), f(lf)]);
        rows.push(vec![s.to_string(), format!("{li:.3}"), format!("{lf:.3}")]);
    }
    common::print_table(
        &["s".to_string(), "l(s) measured".to_string(), "c·s^γ fit".to_string()],
        &rows,
    );

    // the three structural claims of Sec. 3.3
    let non_decreasing = l.windows(2).all(|w| w[1] >= w[0] - 1e-9);
    assert!(non_decreasing, "l(s) must be non-decreasing: {l:?}");
    assert!(fit.is_sublinear(), "γ = {} must be < 1", fit.gamma);
    assert!(fit.r2 > 0.9, "power law fit too poor: r² = {}", fit.r2);
    println!("claims verified: non-decreasing ✓  sublinear (γ<1) ✓  power-law fit (r²>0.9) ✓");

    csv.write_file(common::results_path("fig2_acceptance.csv"))
        .unwrap();
    println!("-> results/fig2_acceptance.csv");

    common::emit_bench_custom(
        "fig2_acceptance",
        Json::obj(vec![
            ("fit_c", Json::Num(fit.c)),
            ("fit_gamma", Json::Num(fit.gamma)),
            ("fit_r2", Json::Num(fit.r2)),
            ("samples", Json::Num(samples.len() as f64)),
        ]),
        Json::obj(vec![
            ("bench", Json::Str("fig2_acceptance".into())),
            ("s_probe", Json::Num(s_probe as f64)),
            ("bucket", Json::Num(bucket as f64)),
            ("scale", Json::Str(common::scale())),
        ]),
    );
}
