//! Shared helpers for the figure-reproduction benches.
//!
//! Each bench is a `harness = false` binary that regenerates one of the
//! paper's figures: it prints the same rows/series the paper reports and
//! writes a CSV under `results/`.  Absolute numbers differ from the
//! paper's RTX 3090 testbed (CPU PJRT + calibrated simulator, see
//! DESIGN.md §Substitutions); the *shape* — who wins, by what factor,
//! where the crossovers fall — is asserted in EXPERIMENTS.md.

use std::path::PathBuf;

use specbatch::metrics::{LatencyRecorder, RoundEvent};
use specbatch::util::json::Json;

/// Artifacts directory, honouring `SPECBATCH_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("SPECBATCH_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

/// Load the runtime, or explain how to build artifacts and exit 0 (so
/// `cargo bench` stays green on a fresh checkout).
#[cfg(feature = "pjrt")]
pub fn load_runtime_or_exit() -> specbatch::runtime::Runtime {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "SKIP: no artifacts at {} — run `make artifacts` first",
            dir.display()
        );
        std::process::exit(0);
    }
    match specbatch::runtime::Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("failed to load artifacts: {e}");
            std::process::exit(1);
        }
    }
}

/// Uniform skip message for real-execution sections in stub-only builds.
#[cfg(not(feature = "pjrt"))]
pub fn skip_real(section: &str) {
    eprintln!(
        "SKIP {section}: real execution needs a `--features pjrt` build \
         (uncomment the `xla` dependency in rust/Cargo.toml, then run \
         `make artifacts`); see DESIGN.md §Feature flags"
    );
}

/// Bench scale: "quick" (CI-sized) or "full" (paper-shaped, default).
pub fn scale() -> String {
    std::env::var("SPECBATCH_BENCH_SCALE").unwrap_or_else(|_| "full".into())
}

pub fn is_quick() -> bool {
    scale() == "quick"
}

/// results/ output path.
pub fn results_path(name: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");
    let _ = std::fs::create_dir_all(&dir);
    dir.join(name)
}

/// Where `BENCH_<name>.json` reports land: `SPECBATCH_RESULTS_DIR` when
/// set (the CI bench job points it somewhere collectable), else the
/// crate's `results/` next to the figure CSVs.
fn bench_results_dir() -> PathBuf {
    std::env::var("SPECBATCH_RESULTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results"))
}

fn write_report(name: &str, report: &Json) {
    let dir = bench_results_dir();
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("BENCH_{name}.json"));
    match report.write_file(&path) {
        Ok(()) => println!("bench report -> {}", path.display()),
        // a read-only results dir must not fail the figure run itself
        Err(e) => eprintln!("bench report write failed: {e}"),
    }
}

/// Emit the machine-readable `BENCH_<name>.json` companion for a figure
/// bench that produced a request recorder (and optionally a round
/// timeline): the full `telemetry::bench` schema — latency percentiles,
/// tokens/s, rounds/s, accepted-per-round, SLO attainment, config
/// fingerprint + git SHA.
pub fn emit_bench(
    name: &str,
    recorder: &LatencyRecorder,
    rounds: &[RoundEvent],
    config: Json,
) {
    let report = specbatch::telemetry::bench::bench_report(name, recorder, rounds, config);
    write_report(name, &report);
}

/// Same, for grid/microbench binaries with no request recorder: the
/// caller passes its headline numbers as a `metrics` object.
pub fn emit_bench_custom(name: &str, metrics: Json, config: Json) {
    let report = specbatch::telemetry::bench::bench_report_custom(name, metrics, config);
    write_report(name, &report);
}

/// Render a small ASCII table (rows of equal length).
pub fn print_table(header: &[String], rows: &[Vec<String>]) {
    let ncol = header.len();
    let mut width = vec![0usize; ncol];
    for (i, h) in header.iter().enumerate() {
        width[i] = h.len();
    }
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            width[i] = width[i].max(c.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:>w$}  ", c, w = width[i]));
        }
        s
    };
    println!("{}", line(header));
    println!("{}", "-".repeat(width.iter().sum::<usize>() + 2 * ncol));
    for row in rows {
        println!("{}", line(row));
    }
}
