//! Attainment-vs-load curve: the three admission controllers under
//! rising traffic intensity, at fixed AND model-based speculation.
//!
//! One stationary deadlined trace per load point (mean inter-arrival
//! interval swept from light to past saturation), replayed against every
//! (controller × policy) pair.  The shape to see:
//!
//! * under light load every controller attains ~100% — admission control
//!   is free when there is no queue;
//! * as load crosses saturation, FIFO attainment collapses first (the
//!   backlog is served in arrival order, deadlines ignored), EDF holds on
//!   longer (urgent requests jump the queue), and SloAware degrades most
//!   gracefully by shedding requests that can no longer meet their SLO;
//! * with *fixed* speculation the policy predicts nothing, so SloAware
//!   degrades to EDF — the gap between the `fixed` and `model` rows is
//!   exactly what the fitted model buys admission control.
//!
//! Output: results/fig_slo_attainment.csv.

#[allow(dead_code)]
mod common;

use specbatch::admission::build_controller;
use specbatch::config::AdmissionSpec;
use specbatch::policy::{Fixed, SpeculationPolicy};
use specbatch::simulator::simulate_trace_continuous_admission;
use specbatch::testkit::harness::{
    const_prompt_pool, paper_sim_config, stationary_trace, warm_model_based,
};
use specbatch::traffic::SloSpec;
use specbatch::util::csv::{f, Csv};
use specbatch::util::json::Json;

const SEED: u64 = 7;

fn main() {
    let n_requests = if common::is_quick() { 150 } else { 500 };
    let intervals = [0.4, 0.2, 0.1, 0.07, 0.05, 0.035, 0.025];
    let pool = const_prompt_pool(12);
    // attainment at the heaviest load point, per (policy, controller) —
    // the numbers the CI trajectory charts
    let mut heavy = std::collections::BTreeMap::new();

    let mut csv = Csv::new(&[
        "interval_s",
        "policy",
        "admission",
        "attainment",
        "met",
        "missed",
        "shed",
        "mean_latency_s",
    ]);
    println!(
        "{:<10} {:<7} {:<10} {:>10} {:>6} {:>7} {:>6} {:>10}",
        "interval", "policy", "admission", "attainment", "met", "missed", "shed", "mean lat"
    );
    for &interval in &intervals {
        for policy_kind in ["fixed", "model"] {
            for spec in AdmissionSpec::all() {
                let mut cfg = paper_sim_config(SEED);
                cfg.max_new_tokens = 32;
                let trace = stationary_trace(&pool, n_requests, SEED, interval, 1.0)
                    .with_deadlines(&SloSpec::new(1.5, 2.0), SEED);
                let mut policy: Box<dyn SpeculationPolicy> = if policy_kind == "fixed" {
                    Box::new(Fixed(2))
                } else {
                    Box::new(warm_model_based(&cfg, 30))
                };
                let mut ctrl = build_controller(spec);
                let (rec, _) = simulate_trace_continuous_admission(
                    &cfg,
                    policy.as_mut(),
                    ctrl.as_mut(),
                    &trace,
                );
                let slo = rec.slo_attainment();
                println!(
                    "{:<10} {:<7} {:<10} {:>9.1}% {:>6} {:>7} {:>6} {:>9.3}s",
                    interval,
                    policy_kind,
                    ctrl.label(),
                    slo.attainment() * 100.0,
                    slo.met,
                    slo.missed,
                    slo.shed,
                    rec.summary().mean
                );
                csv.row(&[
                    f(interval),
                    policy_kind.to_string(),
                    ctrl.label(),
                    f(slo.attainment()),
                    slo.met.to_string(),
                    slo.missed.to_string(),
                    slo.shed.to_string(),
                    f(rec.summary().mean),
                ]);
                if interval == *intervals.last().unwrap() {
                    heavy.insert(
                        format!("attainment_{policy_kind}_{}", ctrl.label()),
                        Json::Num(slo.attainment()),
                    );
                }
            }
        }
        println!();
    }
    csv.write_file("results/fig_slo_attainment.csv")
        .expect("write results/fig_slo_attainment.csv");
    println!("-> results/fig_slo_attainment.csv");

    common::emit_bench_custom(
        "fig_slo_attainment",
        Json::Obj(heavy),
        Json::obj(vec![
            ("bench", Json::Str("fig_slo_attainment".into())),
            ("requests", Json::Num(n_requests as f64)),
            ("seed", Json::Num(SEED as f64)),
            ("heaviest_interval_s", Json::Num(*intervals.last().unwrap())),
            ("scale", Json::Str(common::scale())),
        ]),
    );
}
