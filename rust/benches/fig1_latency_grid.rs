//! Fig. 1 — per-token latency vs speculation length for different batch
//! sizes, models and GPUs; the optimal s per batch is starred.
//!
//! Two reproductions:
//!
//! 1. **Simulator at paper scale** (all six panels): OPT-1.3B/6.7B and
//!    Llama-7B on RTX 3090, plus OPT-6.7B on RTX 4090 and A100, with the
//!    paper's acceptance curve l(s) = 0.9·s^0.548; batch 1..32, s 1..8.
//! 2. **Real execution** on the tiny trained pair via the CPU PJRT
//!    client: batch buckets from the artifact matrix, s 0..6.
//!
//! Output: results/fig1_sim.csv, results/fig1_real.csv + ASCII tables
//! with the per-batch optimum starred.

#[allow(dead_code)]
mod common;

use specbatch::simulator::{
    per_token_latency, AcceptanceProcess, CostModel, GpuProfile, ModelProfile, SimConfig,
};
use specbatch::util::csv::{f, Csv};
use specbatch::util::json::Json;
use specbatch::util::prng::Pcg64;

fn main() {
    sim_grid();
    real_grid();
}

#[cfg(not(feature = "pjrt"))]
fn real_grid() {
    common::skip_real("Fig. 1 real-execution grid");
}

fn sim_grid() {
    println!("== Fig. 1 (simulator, paper scale) ==");
    let panels: Vec<(&str, ModelProfile, GpuProfile)> = vec![
        ("1a", ModelProfile::OPT_1_3B, GpuProfile::RTX3090),
        ("1b", ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        ("1a'", ModelProfile::LLAMA_7B, GpuProfile::RTX3090),
        ("1d", ModelProfile::OPT_6_7B, GpuProfile::RTX4090),
        ("1c", ModelProfile::OPT_6_7B, GpuProfile::A100),
    ];
    let batches = [1usize, 2, 4, 8, 16, 32];
    let slens: Vec<usize> = (0..=8).collect();
    let mut csv = Csv::new(&[
        "panel", "model", "gpu", "batch", "s", "per_token_latency_ms", "is_opt",
    ]);
    let rounds = if common::is_quick() { 100 } else { 500 };
    // per-panel s_opt(b) — the monotone headline the trajectory charts
    let mut s_opts = std::collections::BTreeMap::new();

    for (panel, model, gpu) in &panels {
        let cfg = SimConfig {
            llm: CostModel::new(*model, *gpu),
            ssm: CostModel::new(ModelProfile::OPT_125M, *gpu),
            acceptance: AcceptanceProcess::paper(),
            class_acceptance: Default::default(),
            drift: None,
            max_batch: 32,
            max_new_tokens: 128,
            host_overhead: 0.2e-3,
            kv_layout: specbatch::kvcache::KvLayout::Paged,
            kv_block: specbatch::kvcache::DEFAULT_BLOCK_SIZE,
            prefix_cache: false,
            seed: 1,
        };
        let mut rng = Pcg64::new(42);
        println!("\n-- panel {panel}: {} on {} --", model.name, gpu.name);
        let mut rows = Vec::new();
        for &b in &batches {
            let lat: Vec<f64> = slens
                .iter()
                .map(|&s| per_token_latency(&cfg, b, s, 96, rounds, &mut rng) * 1e3)
                .collect();
            let opt = lat
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            s_opts.insert(
                format!("s_opt_{panel}_b{b}"),
                Json::Num(slens[opt] as f64),
            );
            let mut cells = vec![format!("b={b}")];
            for (i, &l) in lat.iter().enumerate() {
                let star = if i == opt { "*" } else { "" };
                cells.push(format!("{l:.1}{star}"));
                csv.row(&[
                    panel.to_string(),
                    model.name.to_string(),
                    gpu.name.to_string(),
                    b.to_string(),
                    slens[i].to_string(),
                    f(l),
                    ((i == opt) as usize).to_string(),
                ]);
            }
            rows.push(cells);
        }
        let mut header = vec!["batch".to_string()];
        header.extend(slens.iter().map(|s| format!("s={s}")));
        common::print_table(&header, &rows);
    }
    csv.write_file(common::results_path("fig1_sim.csv")).unwrap();
    println!("\n-> results/fig1_sim.csv");

    common::emit_bench_custom(
        "fig1_latency_grid",
        Json::Obj(s_opts),
        Json::obj(vec![
            ("bench", Json::Str("fig1_latency_grid".into())),
            ("rounds", Json::Num(rounds as f64)),
            ("scale", Json::Str(common::scale())),
        ]),
    );
}

#[cfg(feature = "pjrt")]
fn real_grid() {
    use specbatch::policy::{Fixed, NoSpec, SpeculationPolicy};

    println!("\n== Fig. 1 (real execution, tiny models on CPU PJRT) ==");
    let rt = common::load_runtime_or_exit();
    let dataset = rt.dataset().expect("dataset");
    let mut engine =
        specbatch::engine::Engine::new(&rt, specbatch::engine::EngineConfig::default())
            .expect("engine");
    let mut rng = Pcg64::new(3);
    let tokens = if common::is_quick() { 12 } else { 24 };
    let buckets: Vec<usize> = if common::is_quick() {
        vec![1, 2, 4]
    } else {
        rt.manifest.batch_buckets.clone()
    };
    // compile everything up front: lazy compilation must not leak into
    // the timed region (per-token latencies are tens of ms; compiles are
    // seconds)
    let max_b = buckets.iter().copied().max().unwrap();
    rt.warmup(max_b, 8).expect("warmup");

    let mut csv = Csv::new(&["batch", "s", "per_token_latency_ms", "mean_accepted", "is_opt"]);
    let mut rows = Vec::new();
    let slens: Vec<usize> = rt.manifest.verify_lengths.clone();
    for &b in &buckets {
        let mut lat = Vec::new();
        let mut acc = Vec::new();
        for &s in &slens {
            if s > 0 && rt.manifest.max_spec_len(b) < s {
                lat.push(f64::NAN);
                acc.push(0.0);
                continue;
            }
            let prompts: Vec<Vec<i32>> = dataset
                .sample_eval(&mut rng, b)
                .into_iter()
                .map(|p| p.ids)
                .collect();
            let mut policy: Box<dyn SpeculationPolicy> = if s == 0 {
                Box::new(NoSpec)
            } else {
                Box::new(Fixed(s))
            };
            let out = engine
                .generate_batch(&prompts, tokens, policy.as_mut())
                .expect("gen");
            lat.push(out.stats.per_token_latency() * 1e3);
            acc.push(out.stats.mean_accepted());
        }
        let opt = lat
            .iter()
            .enumerate()
            .filter(|(_, l)| l.is_finite())
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let mut cells = vec![format!("b={b}")];
        for (i, &l) in lat.iter().enumerate() {
            if l.is_nan() {
                cells.push("-".into());
                continue;
            }
            let star = if i == opt { "*" } else { "" };
            cells.push(format!("{l:.1}{star}"));
            csv.row(&[
                b.to_string(),
                slens[i].to_string(),
                f(l),
                f(acc[i]),
                ((i == opt) as usize).to_string(),
            ]);
        }
        rows.push(cells);
    }
    let mut header = vec!["batch".to_string()];
    header.extend(slens.iter().map(|s| format!("s={s}")));
    common::print_table(&header, &rows);
    csv.write_file(common::results_path("fig1_real.csv")).unwrap();
    println!("-> results/fig1_real.csv");
}
