//! Fig. 4 — uniform traffic: normalized end-to-end latency of adaptive
//! speculative decoding vs the no-speculation baseline, per fixed batch
//! size (paper: 2.73× speedup at b=1 falling to 1.31× at b=32, 1.94× on
//! average).
//!
//! Reproductions:
//!
//! 1. **Real execution**: profile the LUT on the profile split, then
//!    serve eval prompts grouped into fixed-size batches with
//!    no-spec vs the profiled optimal s; report normalized latency.
//! 2. **Simulator at paper scale** (b up to 32, 128 tokens): same
//!    comparison with the paper's acceptance curve.
//!
//! Output: results/fig4_real.csv, results/fig4_sim.csv.

#[allow(dead_code)]
mod common;

use specbatch::policy::{LutAdaptive, NoSpec};
use specbatch::simulator::{
    batch_service_time, AcceptanceProcess, CostModel, GpuProfile, ModelProfile, SimConfig,
};
use specbatch::util::csv::{f, Csv};
use specbatch::util::json::Json;
use specbatch::util::prng::Pcg64;

fn main() {
    real();
    sim();
}

#[cfg(not(feature = "pjrt"))]
fn real() {
    common::skip_real("Fig. 4 real-execution comparison");
}

#[cfg(feature = "pjrt")]
fn real() {
    use specbatch::engine::{Engine, EngineConfig};
    use specbatch::scheduler::profiler::{profile, ProfilerConfig};

    println!("== Fig. 4 (real execution) ==");
    let rt = common::load_runtime_or_exit();
    let dataset = rt.dataset().expect("dataset");
    let mut engine = Engine::new(&rt, EngineConfig::default()).expect("engine");
    // keep compilation out of every timed region (profiling included)
    let max_b = rt.manifest.batch_buckets.iter().copied().max().unwrap();
    rt.warmup(max_b, 8).expect("warmup");

    // offline profiling on the profile split (the adaptive scheme)
    let mut rng = Pcg64::new(0xADA);
    let profile_prompts = dataset.sample_profile(&mut rng, 24);
    let mut pcfg = ProfilerConfig::from_manifest(&rt.manifest);
    if common::is_quick() {
        pcfg.tokens_per_run = 8;
        pcfg.repeats = 1;
    }
    let lut = profile(&mut engine, &profile_prompts, &pcfg)
        .expect("profiling")
        .lut;
    println!("adaptive LUT: {}", lut.to_json().compact());

    let buckets: Vec<usize> = if common::is_quick() {
        vec![1, 2, 4]
    } else {
        rt.manifest.batch_buckets.clone()
    };
    let tokens = if common::is_quick() { 12 } else { 32 };
    let batches_per_point = if common::is_quick() { 1 } else { 3 };

    let mut csv = Csv::new(&[
        "batch",
        "nospec_ms_per_token",
        "adaptive_ms_per_token",
        "normalized_latency",
        "speedup",
        "s_used",
    ]);
    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    let mut rng = Pcg64::new(0xF4);
    for &b in &buckets {
        let mut t_nospec = 0.0;
        let mut t_adaptive = 0.0;
        for _ in 0..batches_per_point {
            let prompts: Vec<Vec<i32>> = dataset
                .sample_eval(&mut rng, b)
                .into_iter()
                .map(|p| p.ids)
                .collect();
            let o1 = engine
                .generate_batch(&prompts, tokens, &mut NoSpec)
                .expect("nospec");
            let o2 = engine
                .generate_batch(&prompts, tokens, &mut LutAdaptive(lut.clone()))
                .expect("adaptive");
            t_nospec += o1.stats.per_token_latency();
            t_adaptive += o2.stats.per_token_latency();
        }
        let norm = t_adaptive / t_nospec;
        let speedup = 1.0 / norm;
        speedups.push(speedup);
        let s_used = lut.lookup(b);
        csv.row(&[
            b.to_string(),
            f(t_nospec / batches_per_point as f64 * 1e3),
            f(t_adaptive / batches_per_point as f64 * 1e3),
            f(norm),
            f(speedup),
            s_used.to_string(),
        ]);
        rows.push(vec![
            format!("b={b}"),
            format!("{:.3}", norm),
            format!("{speedup:.2}x"),
            format!("s={s_used}"),
        ]);
    }
    common::print_table(
        &[
            "batch".into(),
            "normalized latency".into(),
            "speedup".into(),
            "adaptive s".into(),
        ],
        &rows,
    );
    let avg = speedups.iter().product::<f64>().powf(1.0 / speedups.len() as f64);
    println!("geo-mean speedup: {avg:.2}x (paper: 1.94x avg, 2.73x at b=1, 1.31x at b=32)");
    csv.write_file(common::results_path("fig4_real.csv")).unwrap();
    println!("-> results/fig4_real.csv\n");
}

fn sim() {
    println!("== Fig. 4 (simulator, paper scale: OPT-6.7B / RTX 3090) ==");
    let cfg = SimConfig {
        llm: CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        ssm: CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        acceptance: AcceptanceProcess::paper(),
        class_acceptance: Default::default(),
        drift: None,
        max_batch: 32,
        max_new_tokens: 128,
        host_overhead: 0.2e-3,
        kv_layout: specbatch::kvcache::KvLayout::Paged,
        kv_block: specbatch::kvcache::DEFAULT_BLOCK_SIZE,
        prefix_cache: false,
        seed: 7,
    };
    let lut = specbatch::simulator::simulated_lut(&cfg, &[1, 2, 4, 8, 16, 32], 8, 80);
    println!("simulated LUT: {}", lut.to_json().compact());
    let mut rng = Pcg64::new(0x5f4);
    let reps = if common::is_quick() { 3 } else { 10 };

    let mut csv = Csv::new(&["batch", "normalized_latency", "speedup", "s_used"]);
    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    for &b in &[1usize, 2, 4, 8, 16, 32] {
        let plens = vec![16usize; b];
        let mut t0 = 0.0;
        let mut t1 = 0.0;
        for _ in 0..reps {
            t0 += batch_service_time(&cfg, &mut NoSpec, &plens, 0.0, &mut rng).0;
            t1 += batch_service_time(
                &cfg,
                &mut LutAdaptive(lut.clone()),
                &plens,
                0.0,
                &mut rng,
            )
            .0;
        }
        let norm = t1 / t0;
        let speedup = 1.0 / norm;
        speedups.push(speedup);
        csv.row(&[
            b.to_string(),
            f(norm),
            f(speedup),
            lut.lookup(b).to_string(),
        ]);
        rows.push(vec![
            format!("b={b}"),
            format!("{norm:.3}"),
            format!("{speedup:.2}x"),
            format!("s={}", lut.lookup(b)),
        ]);
    }
    common::print_table(
        &[
            "batch".into(),
            "normalized latency".into(),
            "speedup".into(),
            "adaptive s".into(),
        ],
        &rows,
    );
    let avg = speedups.iter().product::<f64>().powf(1.0 / speedups.len() as f64);
    println!("geo-mean speedup: {avg:.2}x (paper: 1.94x avg; 2.73x @ b=1 -> 1.31x @ b=32)");
    csv.write_file(common::results_path("fig4_sim.csv")).unwrap();
    println!("-> results/fig4_sim.csv");

    common::emit_bench_custom(
        "fig4_uniform",
        Json::obj(vec![
            ("speedup_geo", Json::Num(avg)),
            ("speedup_b1", Json::Num(speedups[0])),
            ("speedup_b32", Json::Num(*speedups.last().unwrap())),
        ]),
        Json::obj(vec![
            ("bench", Json::Str("fig4_uniform".into())),
            ("reps", Json::Num(reps as f64)),
            ("seed", Json::Num(cfg.seed as f64)),
            ("scale", Json::Str(common::scale())),
        ]),
    );
}
