//! Prefix-sharing payoff figure: shared-prompt traffic served with the
//! prefix cache on vs off (ISSUE PR 10 tentpole).
//!
//! Scenario: the multi-tenant template workload of
//! `Trace::with_shared_prefix` — every prompt is a Zipf-weighted
//! (tenant, template) system prefix (96 of 100 tokens with the default
//! spec) plus a tiny unique user tail.  The prefix cache maps the shared
//! blocks read-only at admission, so the LLM prefill shrinks to the
//! unmatched suffix.
//!
//! Claims pinned here (and gated in tests/prefix_sharing.rs):
//!   * charged prefill tokens drop by >= 10x once the working set is
//!     resident (seeds {2, 3, 4});
//!   * mean TTFT is strictly better with the cache on, same trace;
//!   * the cache never hurts end-to-end mean latency.
//!
//! Output: results/fig_prefix_sharing.csv + BENCH_prefix_sharing.json.

#[allow(dead_code)]
mod common;

use specbatch::admission::Fifo;
use specbatch::policy::Fixed;
use specbatch::simulator::{
    simulate_trace_continuous_admission_tel_prefix, AcceptanceProcess, CostModel, GpuProfile,
    ModelProfile, SimConfig,
};
use specbatch::telemetry::Telemetry;
use specbatch::traffic::{SharedPrefixSpec, Trace, TrafficPattern};
use specbatch::util::csv::{f, Csv};
use specbatch::util::json::Json;

fn main() {
    let base = SimConfig {
        llm: CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        ssm: CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        acceptance: AcceptanceProcess::paper(),
        class_acceptance: Default::default(),
        drift: None,
        max_batch: 16,
        max_new_tokens: 64,
        host_overhead: 0.2e-3,
        kv_layout: specbatch::kvcache::KvLayout::Paged,
        kv_block: specbatch::kvcache::DEFAULT_BLOCK_SIZE,
        prefix_cache: false,
        seed: 5,
    };
    let spec = SharedPrefixSpec::default();
    // enough requests that the resident working set amortises the cold
    // misses (16 (tenant, template) chains; ~200 requests only reach ~9x)
    let n_requests = if common::is_quick() { 600 } else { 1000 };

    let mut csv = Csv::new(&[
        "seed",
        "cache",
        "mean_latency_s",
        "mean_ttft_s",
        "ttft_p99_s",
        "prefill_tokens_charged",
        "hit_rate",
    ]);
    let mut rows = Vec::new();
    let mut cuts = Vec::new();
    let mut ttft_gains = Vec::new();
    let mut hit_rates = Vec::new();

    for seed in [2u64, 3, 4] {
        let pattern = TrafficPattern::Stationary {
            interval: 0.05,
            cv: 1.0,
        };
        // with_shared_prefix replaces every prompt, so the pool is a stub
        let pool = vec![specbatch::dataset::Prompt {
            ids: vec![1; 8],
            text: String::new(),
        }];
        let trace = Trace::generate(&pattern, &pool, n_requests, seed)
            .with_shared_prefix(&spec, seed);
        let total_plen: usize = trace.items.iter().map(|it| it.prompt.ids.len()).sum();

        let mut run = |on: bool| {
            let cfg = SimConfig {
                prefix_cache: on,
                seed,
                ..base.clone()
            };
            simulate_trace_continuous_admission_tel_prefix(
                &cfg,
                &mut Fixed(2),
                &mut Fifo,
                &trace,
                &Telemetry::disabled(),
            )
        };

        let (rec_off, _, stats_off) = run(false);
        let (rec_on, _, stats_on) = run(true);
        assert!(stats_off.is_none(), "cache off must not build a prefix index");
        let stats = stats_on.expect("cache on returns stats");

        let charged_off = total_plen as f64;
        let charged_on = total_plen as f64 - stats.prefill_tokens_saved as f64;
        let cut = charged_off / charged_on.max(1.0);
        let (ttft_off, ttft_on) = (rec_off.mean_ttft(), rec_on.mean_ttft());
        let (_, _, ttft_p99_off) = rec_off.ttft_percentiles();
        let (_, _, ttft_p99_on) = rec_on.ttft_percentiles();

        csv.row(&[
            seed.to_string(),
            "off".into(),
            f(rec_off.summary().mean),
            f(ttft_off),
            f(ttft_p99_off),
            f(charged_off),
            f(0.0),
        ]);
        csv.row(&[
            seed.to_string(),
            "on".into(),
            f(rec_on.summary().mean),
            f(ttft_on),
            f(ttft_p99_on),
            f(charged_on),
            f(stats.hit_rate()),
        ]);
        rows.push(vec![
            format!("{seed}"),
            format!("{:.3}", ttft_off),
            format!("{:.3}", ttft_on),
            format!("{:.1}x", cut),
            format!("{:.1}%", stats.hit_rate() * 100.0),
        ]);
        cuts.push(cut);
        ttft_gains.push(ttft_off / ttft_on.max(1e-12));
        hit_rates.push(stats.hit_rate());

        assert!(
            cut >= 10.0,
            "seed {seed}: prefill cut {cut:.2}x below the 10x bar"
        );
        assert!(
            ttft_on < ttft_off,
            "seed {seed}: TTFT must strictly improve ({ttft_on:.4}s vs {ttft_off:.4}s)"
        );
    }

    common::print_table(
        &[
            "seed".into(),
            "ttft off".into(),
            "ttft on".into(),
            "prefill cut".into(),
            "hit rate".into(),
        ],
        &rows,
    );

    let geo = |v: &[f64]| v.iter().product::<f64>().powf(1.0 / v.len() as f64);
    println!(
        "\nprefill cut: {:.1}x geomean | TTFT gain: {:.2}x geomean | hit rate: {:.1}% mean",
        geo(&cuts),
        geo(&ttft_gains),
        hit_rates.iter().sum::<f64>() / hit_rates.len() as f64 * 100.0
    );

    csv.write_file(common::results_path("fig_prefix_sharing.csv"))
        .unwrap();
    println!("-> results/fig_prefix_sharing.csv");

    common::emit_bench_custom(
        "prefix_sharing",
        Json::obj(vec![
            ("prefill_cut_geo", Json::Num(geo(&cuts))),
            ("ttft_gain_geo", Json::Num(geo(&ttft_gains))),
            (
                "hit_rate_mean",
                Json::Num(hit_rates.iter().sum::<f64>() / hit_rates.len() as f64),
            ),
        ]),
        Json::obj(vec![
            ("bench", Json::Str("prefix_sharing".into())),
            ("requests", Json::Num(n_requests as f64)),
            ("tenants", Json::Num(spec.tenants as f64)),
            ("templates", Json::Num(spec.templates as f64)),
            ("scale", Json::Str(common::scale())),
        ]),
    );
}
