//! Cluster scaling × routing sweep: workers ∈ {1, 2, 4, 8} and all four
//! routing strategies on one shared intense trace (paper-scale cost
//! model, per-shard model-based speculation).  The shape to see:
//!
//! * adding workers cuts mean latency while arrivals saturate a single
//!   worker's service rate;
//! * at fixed worker count, state-aware routing (JSQ / power-of-two /
//!   cost-aware) beats round-robin, and the cost-aware router — reading
//!   each shard's fitted batch↔s_opt curve — is at least as good as the
//!   load-only strategies.
//!
//! Output: results/cluster_scaling.csv.

#[allow(dead_code)]
mod common;

use specbatch::cluster::sim::simulate_trace_cluster;
use specbatch::cluster::{build_router, replicate_policies};
use specbatch::config::{PolicySpec, RouterSpec};
use specbatch::dataset::Prompt;
use specbatch::simulator::{
    simulated_lut, CostModel, GpuProfile, ModelProfile, SimConfig,
};
use specbatch::traffic::{Trace, TrafficPattern};
use specbatch::util::csv::{f, Csv};
use specbatch::util::json::Json;

fn main() {
    let cfg = SimConfig {
        seed: 14,
        ..SimConfig::paper_default(
            CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
            CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        )
    };
    let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
    println!("offline LUT: {}", lut.to_json().compact());

    let n_requests = if common::is_quick() { 300 } else { 1200 };
    let pool = vec![Prompt {
        ids: vec![1; 16],
        text: String::new(),
    }];
    // intense enough to queue hard on one worker, bursty (cv 2) so the
    // oblivious router visibly misplaces work
    let trace = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.08,
            cv: 2.0,
        },
        &pool,
        n_requests,
        77,
    );
    println!("trace: {} requests over {:.0}s\n", trace.len(), trace.span());

    let mut csv = Csv::new(&[
        "workers",
        "router",
        "mean_latency_s",
        "p90_latency_s",
        "ms_per_token",
        "max_shard_spread",
    ]);
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut at4: Vec<(String, f64)> = Vec::new();
    let mut rr_by_workers: Vec<(usize, f64)> = Vec::new();
    // the CI trajectory point: 4 workers under the cost-aware router
    let mut headline: Option<(
        specbatch::metrics::LatencyRecorder,
        Vec<specbatch::metrics::RoundEvent>,
    )> = None;
    for workers in [1usize, 2, 4, 8] {
        for spec in RouterSpec::all() {
            let mut policies =
                replicate_policies(&PolicySpec::ModelBased, Some(&lut), workers)
                    .expect("LUT provided");
            let mut router = build_router(spec, cfg.seed);
            let report =
                simulate_trace_cluster(&cfg, &mut policies, router.as_mut(), &trace);
            assert_eq!(report.recorder.len(), n_requests);
            let mean = report.recorder.summary().mean;
            let (_, p90, _) = report.recorder.percentiles();
            let per_token = report.recorder.mean_per_token_latency() * 1e3;
            let counts = report.shard_requests();
            let spread = counts.iter().max().unwrap() - counts.iter().min().unwrap();
            csv.row(&[
                workers.to_string(),
                report.router.clone(),
                f(mean),
                f(p90),
                f(per_token),
                spread.to_string(),
            ]);
            rows.push(vec![
                workers.to_string(),
                report.router.clone(),
                format!("{mean:.3}"),
                format!("{p90:.3}"),
                format!("{per_token:.2}"),
            ]);
            if workers == 4 {
                at4.push((report.router.clone(), mean));
                if spec == RouterSpec::CostAware {
                    let mut merged: Vec<specbatch::metrics::RoundEvent> =
                        report.shard_rounds.iter().flatten().copied().collect();
                    merged.sort_by(|a, b| a.t.total_cmp(&b.t));
                    headline = Some((report.recorder.clone(), merged));
                }
            }
            if spec == RouterSpec::RoundRobin {
                rr_by_workers.push((workers, mean));
            }
        }
    }
    common::print_table(
        &[
            "workers".into(),
            "router".into(),
            "mean (s)".into(),
            "p90 (s)".into(),
            "ms/token".into(),
        ],
        &rows,
    );

    // shape assertions
    let rr = |w: usize| rr_by_workers.iter().find(|&&(n, _)| n == w).unwrap().1;
    assert!(
        rr(4) < rr(1),
        "4 workers ({:.3}s) must beat 1 ({:.3}s) under this load",
        rr(4),
        rr(1)
    );
    let get4 = |n: &str| at4.iter().find(|(m, _)| m == n).unwrap().1;
    if !common::is_quick() {
        // the routing margin needs the full trace to rise above placement
        // noise; quick mode only checks the sweep runs end to end
        assert!(
            get4("cost-aware") <= get4("round-robin"),
            "cost-aware ({:.3}s) should not lose to round-robin ({:.3}s) at 4 workers",
            get4("cost-aware"),
            get4("round-robin")
        );
        println!("\nshape verified: scaling helps ✓  cost-aware ≤ round-robin at 4 workers ✓");
    } else {
        println!("\nshape verified: scaling helps ✓  (routing margin asserted at full scale)");
    }

    csv.write_file(common::results_path("cluster_scaling.csv"))
        .unwrap();
    println!("-> results/cluster_scaling.csv");

    if let Some((recorder, rounds)) = &headline {
        common::emit_bench(
            "cluster_scaling",
            recorder,
            rounds,
            Json::obj(vec![
                ("bench", Json::Str("cluster_scaling".into())),
                ("workers", Json::Num(4.0)),
                ("router", Json::Str("cost-aware".into())),
                ("requests", Json::Num(n_requests as f64)),
                ("seed", Json::Num(cfg.seed as f64)),
                ("scale", Json::Str(common::scale())),
            ]),
        );
    }
}
