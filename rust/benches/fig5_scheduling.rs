//! Fig. 5 × scheduling mode — the new scenario axis opened by the
//! continuous-batching subsystem: average request latency over the
//! traffic-volume grid (mean interval 0.1..0.8 s, CV = 1) for every
//! policy under **static** (batch-to-completion, the paper's server) vs
//! **continuous** (round-granular admission/retirement) scheduling.
//!
//! Expected shape: continuous batching dominates static wherever the
//! server queues (intense traffic), because arrivals no longer wait for a
//! whole batch to complete; and the adaptive policy gains the most from
//! it, since the live batch size — and with it the chosen `s` — now
//! changes within a single serving epoch.
//!
//! Runs at paper scale on the calibrated simulator (OPT-6.7B + OPT-125M
//! on RTX 3090, max batch 16, 128 tokens per request, one shared trace
//! per cell across all policies and both modes).
//!
//! Output: results/fig5_scheduling.csv + an ASCII table per interval.

#[allow(dead_code)]
mod common;

use specbatch::dataset::Prompt;
use specbatch::simulator::{
    comparison_policies, simulate_trace, simulate_trace_continuous, simulated_lut,
    AcceptanceProcess, CostModel, GpuProfile, ModelProfile, SimConfig,
};
use specbatch::traffic::{Trace, TrafficPattern};
use specbatch::util::csv::{f, Csv};
use specbatch::util::json::Json;

fn main() {
    let cfg = SimConfig {
        llm: CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        ssm: CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        acceptance: AcceptanceProcess::paper(),
        class_acceptance: Default::default(),
        drift: None,
        max_batch: 16,
        max_new_tokens: 128,
        host_overhead: 0.2e-3,
        kv_layout: specbatch::kvcache::KvLayout::Paged,
        kv_block: specbatch::kvcache::DEFAULT_BLOCK_SIZE,
        prefix_cache: false,
        seed: 9,
    };
    let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
    println!("simulated LUT: {}", lut.to_json().compact());
    let mut policies = comparison_policies(lut);

    let n_requests = if common::is_quick() { 200 } else { 1000 };
    let intervals = [0.1, 0.2, 0.3, 0.4, 0.6, 0.8];
    let pool: Vec<Prompt> = (4..=24)
        .map(|n| Prompt {
            ids: vec![1; n],
            text: String::new(),
        })
        .collect();

    let mut csv = Csv::new(&[
        "interval_s",
        "policy",
        "mode",
        "mean_latency_s",
        "p99_s",
        "static_over_continuous",
    ]);
    let mut overall_gain: Vec<f64> = Vec::new();

    for &interval in &intervals {
        let trace = Trace::generate(
            &TrafficPattern::Stationary { interval, cv: 1.0 },
            &pool,
            n_requests,
            100 + (interval * 100.0) as u64,
        );
        println!("\n-- interval {interval}s (cv 1.0, {n_requests} requests) --");
        let mut rows = Vec::new();
        for (name, policy) in policies.iter_mut() {
            let rec_static = simulate_trace(&cfg, policy.as_mut(), &trace);
            let (rec_cont, _rounds) = simulate_trace_continuous(&cfg, policy.as_mut(), &trace);
            let m_static = rec_static.summary().mean;
            let m_cont = rec_cont.summary().mean;
            let (_, _, p99_static) = rec_static.percentiles();
            let (_, _, p99_cont) = rec_cont.percentiles();
            let gain = m_static / m_cont;
            overall_gain.push(gain);
            csv.row(&[
                f(interval),
                name.clone(),
                "static".into(),
                f(m_static),
                f(p99_static),
                f(gain),
            ]);
            csv.row(&[
                f(interval),
                name.clone(),
                "continuous".into(),
                f(m_cont),
                f(p99_cont),
                f(gain),
            ]);
            rows.push(vec![
                name.clone(),
                format!("{m_static:.3}s"),
                format!("{m_cont:.3}s"),
                format!("{gain:.2}x"),
            ]);
        }
        common::print_table(
            &[
                "policy".into(),
                "static mean".into(),
                "continuous mean".into(),
                "static/continuous".into(),
            ],
            &rows,
        );
    }

    let geo = overall_gain
        .iter()
        .product::<f64>()
        .powf(1.0 / overall_gain.len() as f64);
    println!("\ngeo-mean static/continuous latency ratio across the grid: {geo:.2}x");
    csv.write_file(common::results_path("fig5_scheduling.csv"))
        .unwrap();
    println!("-> results/fig5_scheduling.csv");

    common::emit_bench_custom(
        "fig5_scheduling",
        Json::obj(vec![("static_over_continuous_geo", Json::Num(geo))]),
        Json::obj(vec![
            ("bench", Json::Str("fig5_scheduling".into())),
            ("requests_per_cell", Json::Num(n_requests as f64)),
            ("scale", Json::Str(common::scale())),
        ]),
    );
}
