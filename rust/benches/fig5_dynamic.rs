//! Fig. 5 — dynamic traffic: average request latency over a grid of
//! traffic volumes (mean interval 0.1..0.8 s) and burstiness (CV ∈
//! {0.5, 1, 2, 5}) for the four comparison points: no speculation,
//! fixed-2, fixed-4, adaptive.
//!
//! Paper claims to reproduce in *shape*: adaptive ≥ best fixed everywhere
//! (avg 2.3× over no-spec; up to 1.15× over the better fixed scheme at
//! high CV); fixed-2 wins at intense traffic, fixed-4 at sparse traffic.
//!
//! Reproduction runs at paper scale on the calibrated simulator
//! (OPT-6.7B + OPT-125M on RTX 3090, 1000 requests per cell, max batch
//! 16, 128 tokens per request, one shared trace per cell across all
//! policies — exactly the paper's methodology).  A scaled-down *real*
//! server/client run of one column lives in the `serve_dynamic` example.
//!
//! Output: results/fig5_dynamic.csv + per-CV ASCII tables.

#[allow(dead_code)]
mod common;

use specbatch::dataset::Prompt;
use specbatch::simulator::{
    comparison_policies, simulate_trace, simulated_lut, AcceptanceProcess, CostModel,
    GpuProfile, ModelProfile, SimConfig,
};
use specbatch::traffic::{Trace, TrafficPattern};
use specbatch::util::csv::{f, Csv};
use specbatch::util::json::Json;

fn main() {
    let cfg = SimConfig {
        llm: CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        ssm: CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        acceptance: AcceptanceProcess::paper(),
        class_acceptance: Default::default(),
        drift: None,
        max_batch: 16,
        max_new_tokens: 128,
        host_overhead: 0.2e-3,
        kv_layout: specbatch::kvcache::KvLayout::Paged,
        kv_block: specbatch::kvcache::DEFAULT_BLOCK_SIZE,
        prefix_cache: false,
        seed: 5,
    };
    let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
    println!("simulated LUT: {}", lut.to_json().compact());
    let mut policies = comparison_policies(lut);

    let n_requests = if common::is_quick() { 200 } else { 1000 };
    let cvs = [0.5, 1.0, 2.0, 5.0];
    let intervals = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];
    // prompt lengths sampled like the dataset's 4..24 range
    let pool: Vec<Prompt> = (4..=24)
        .map(|n| Prompt {
            ids: vec![1; n],
            text: String::new(),
        })
        .collect();

    let mut csv = Csv::new(&["cv", "interval_s", "policy", "mean_latency_s", "p99_s"]);
    let mut adaptive_vs_best_fixed = Vec::new();
    let mut adaptive_vs_nospec = Vec::new();

    for &cv in &cvs {
        println!("\n-- CV = {cv} --");
        let mut rows = Vec::new();
        for &interval in &intervals {
            // ONE trace per cell, shared by all policies (paper Sec. 5.3)
            let trace = Trace::generate(
                &TrafficPattern::Stationary { interval, cv },
                &pool,
                n_requests,
                (cv * 1000.0) as u64 + (interval * 100.0) as u64,
            );
            let mut cells = vec![format!("{interval:.1}s")];
            let mut cell_means = Vec::new();
            for (name, policy) in policies.iter_mut() {
                let rec = simulate_trace(&cfg, policy.as_mut(), &trace);
                assert_eq!(rec.len(), n_requests);
                let mean = rec.summary().mean;
                let (_, _, p99) = rec.percentiles();
                csv.row(&[
                    f(cv),
                    f(interval),
                    name.clone(),
                    f(mean),
                    f(p99),
                ]);
                cells.push(format!("{mean:.2}"));
                cell_means.push(mean);
            }
            // adaptive (idx 3) vs best fixed (idx 1, 2) and no-spec (idx 0)
            let best_fixed = cell_means[1].min(cell_means[2]);
            adaptive_vs_best_fixed.push(best_fixed / cell_means[3]);
            adaptive_vs_nospec.push(cell_means[0] / cell_means[3]);
            rows.push(cells);
        }
        common::print_table(
            &[
                "interval".into(),
                "no-spec".into(),
                "fixed-2".into(),
                "fixed-4".into(),
                "adaptive".into(),
            ],
            &rows,
        );
    }

    let geo = |v: &[f64]| v.iter().product::<f64>().powf(1.0 / v.len() as f64);
    println!(
        "\nadaptive vs no-spec: {:.2}x avg (paper: 2.3x)",
        geo(&adaptive_vs_nospec)
    );
    println!(
        "adaptive vs best-fixed: {:.3}x avg, {:.3}x max (paper: 1.07x avg, 1.15x max at high CV)",
        geo(&adaptive_vs_best_fixed),
        adaptive_vs_best_fixed
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max)
    );

    csv.write_file(common::results_path("fig5_dynamic.csv"))
        .unwrap();
    println!("-> results/fig5_dynamic.csv");

    common::emit_bench_custom(
        "fig5_dynamic",
        Json::obj(vec![
            ("adaptive_vs_nospec_geo", Json::Num(geo(&adaptive_vs_nospec))),
            (
                "adaptive_vs_best_fixed_geo",
                Json::Num(geo(&adaptive_vs_best_fixed)),
            ),
            (
                "adaptive_vs_best_fixed_max",
                Json::Num(
                    adaptive_vs_best_fixed
                        .iter()
                        .cloned()
                        .fold(f64::NEG_INFINITY, f64::max),
                ),
            ),
        ]),
        Json::obj(vec![
            ("bench", Json::Str("fig5_dynamic".into())),
            ("requests_per_cell", Json::Num(n_requests as f64)),
            ("scale", Json::Str(common::scale())),
        ]),
    );

    // structural assertions (the shape the paper reports)
    assert!(
        geo(&adaptive_vs_nospec) > 1.5,
        "adaptive should clearly beat no-spec"
    );
    assert!(
        geo(&adaptive_vs_best_fixed) > 0.97,
        "adaptive should be on par with or better than the best fixed scheme"
    );
}
