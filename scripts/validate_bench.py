#!/usr/bin/env python3
"""Schema validator for specbatch observability artifacts.  Stdlib only.

Two modes:

* default — validate `BENCH_*.json` bench reports (`telemetry::bench`
  schema): required top-level keys, a non-empty numeric `metrics` map,
  a `config` object, and a well-formed FNV-1a `config_fingerprint`.
* `--events` — validate a telemetry/flight-recorder events JSONL file:
  every line parses as a JSON object carrying `ev` + `t`; a leading
  `flight_dump` header (flight dumps always start with one) must name
  at least one trigger cause and a record count.

Usage:
    validate_bench.py BENCH_a.json [BENCH_b.json ...]
    validate_bench.py --events dump.jsonl [more.jsonl ...]

Exit status: 1 on the first schema violation, else 0.
"""

import argparse
import json
import sys
from pathlib import Path

KNOWN_EVS = {
    "round",
    "phase",
    "admission",
    "finish",
    "route",
    "policy_fit",
    "kv_pool",
    "trigger",
    "flight_dump",
}


def fail(path: Path, msg: str) -> None:
    sys.exit(f"validate-bench: {path}: {msg}")


def validate_bench(path: Path) -> None:
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        fail(path, f"cannot read/parse: {e}")
    if not isinstance(doc, dict):
        fail(path, "top level is not an object")
    for key in ("name", "config", "config_fingerprint", "metrics"):
        if key not in doc:
            fail(path, f"missing required key {key!r}")
    if not isinstance(doc["name"], str) or not doc["name"]:
        fail(path, "name must be a non-empty string")
    if not isinstance(doc["config"], dict):
        fail(path, "config must be an object")
    fp = doc["config_fingerprint"]
    if not (isinstance(fp, str) and len(fp) == 16 and all(c in "0123456789abcdef" for c in fp)):
        fail(path, f"config_fingerprint {fp!r} is not 16 lowercase hex chars")
    metrics = doc["metrics"]
    if not isinstance(metrics, dict) or not metrics:
        fail(path, "metrics must be a non-empty object")
    for k, v in metrics.items():
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            fail(path, f"metric {k!r} is not a number: {v!r}")
        if v != v or v in (float("inf"), float("-inf")):
            fail(path, f"metric {k!r} is not finite: {v!r}")
    # recorder-backed reports carry the latency block; grids don't —
    # when present it must be structurally sound
    ptl = doc.get("per_token_latency_s")
    if ptl is not None:
        for q in ("mean", "p50", "p99"):
            if not isinstance(ptl.get(q), (int, float)):
                fail(path, f"per_token_latency_s.{q} missing or non-numeric")
    print(f"validate-bench: OK {path} ({len(metrics)} metrics)")


def validate_events(path: Path) -> None:
    try:
        lines = path.read_text().splitlines()
    except OSError as e:
        fail(path, f"cannot read: {e}")
    if not lines:
        fail(path, "empty events file")
    n_rounds = 0
    for i, line in enumerate(lines, 1):
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            fail(path, f"line {i} is not valid JSON: {e}")
        if not isinstance(obj, dict):
            fail(path, f"line {i} is not an object")
        ev = obj.get("ev")
        if ev not in KNOWN_EVS:
            fail(path, f"line {i}: unknown ev {ev!r}")
        if not isinstance(obj.get("t"), (int, float)):
            fail(path, f"line {i}: missing numeric t")
        if i == 1 and ev == "flight_dump":
            causes = obj.get("causes")
            if not isinstance(causes, list) or not causes:
                fail(path, "flight_dump header names no trigger causes")
            if not isinstance(obj.get("records"), int):
                fail(path, "flight_dump header missing record count")
        if ev == "round":
            n_rounds += 1
    if n_rounds == 0:
        fail(path, "no round events — the captured window is useless")
    print(f"validate-bench: OK {path} ({len(lines)} events, {n_rounds} rounds)")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+", type=Path)
    ap.add_argument(
        "--events",
        action="store_true",
        help="validate telemetry/flight JSONL instead of bench reports",
    )
    args = ap.parse_args()
    for path in args.paths:
        if args.events:
            validate_events(path)
        else:
            validate_bench(path)
    return 0


if __name__ == "__main__":
    sys.exit(main())
