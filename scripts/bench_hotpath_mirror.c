/* C mirror of `cargo bench --bench micro_hotpath`'s stub-backend
 * rounds/s grid — see bench_hotpath_mirror.py (which compiles and runs
 * this) for why a mirror exists at all.
 *
 * Two implementations of the same decode round over the same stub model
 * (splitmix64 Markov chain on the last token, constants from
 * rust/src/testkit/stub.rs):
 *
 *   before — the pre-refactor shape: rows as an array-of-structs, each
 *   row owning its own heap-grown token buffer, and every round
 *   malloc'ing fresh feed/draft/pred/commit/accepted batch vectors plus
 *   a cloned per-round stats record (the Vec-per-round churn the old
 *   `decode_round` did);
 *
 *   after — the post-refactor shape: one flat token arena with a fixed
 *   row stride (RowSoa) plus round-scratch buffers allocated once and
 *   written in place (RoundScratch).  The round loop performs zero heap
 *   allocations.
 *
 * Because this is native code with real malloc economics and the stub
 * model costs nanoseconds per token (exactly as in Rust), the measured
 * before/after delta isolates the allocation discipline and memory
 * layout — the thing the PR changed.  Both variants must produce
 * byte-identical token streams; the program aborts if they diverge.
 *
 * Output: one line per grid cell, "b s rps_before rps_after".
 */

#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#define VOCAB 512
#define AGREEMENT_PCT 80
#define STUB_SEED 0xB007ULL
#define LLM_SALT 0x5eed11ULL
#define PROMPT_LEN 8
#define STRIDE 2048

static uint64_t splitmix64(uint64_t x) {
    x += 0x9e3779b97f4a7c15ULL;
    uint64_t z = x;
    z = (z ^ (z >> 30)) * 0xbf58476d1ce4e5b9ULL;
    z = (z ^ (z >> 27)) * 0x94d049bb133111ebULL;
    return z ^ (z >> 31);
}

/* stub model: next LLM token and next SSM draft token, both functions
 * of the previous token only (rust/src/testkit/stub.rs) */
static int32_t llm_next(int32_t t) {
    return (int32_t)(4 + splitmix64((uint64_t)t ^ LLM_SALT) % (VOCAB - 4));
}

static int32_t ssm_next(int32_t t) {
    int32_t llm = llm_next(t);
    if (splitmix64((uint64_t)t ^ STUB_SEED) % 100 < AGREEMENT_PCT) {
        return llm;
    }
    return 4 + (llm - 4 + 1) % (VOCAB - 4);
}

static void make_prompt(int row, uint64_t seed, int32_t *out) {
    for (int k = 0; k < PROMPT_LEN; k++) {
        out[k] = (int32_t)(4 + splitmix64(seed + (uint64_t)(row * 131 + k)) %
                                   (VOCAB - 4));
    }
}

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + (double)ts.tv_nsec * 1e-9;
}

static void *xmalloc(size_t n) {
    void *p = malloc(n);
    if (!p) {
        fprintf(stderr, "oom\n");
        exit(1);
    }
    return p;
}

/* ---- before: AoS rows + per-round Vec churn -------------------------- */

typedef struct {
    int32_t *tokens; /* per-row heap buffer, grown by doubling (Vec) */
    int len;
    int cap;
} Row;

typedef struct {
    uint32_t *accepted; /* cloned per round (GenStats push) */
} RoundStats;

/* accept_row used to return an owned commit Vec per row */
typedef struct {
    int accepted;
    int32_t *commit;
    int commit_len;
} RowAcceptance;

/* Allocation inventory of the old `round_speculative` (one round):
 *   build_delta     -> delta (b*2) + dlens (b)
 *   stub speculate  -> draft Vec (b*s)
 *   verify staging  -> feed vec![0; b*(s+1)]   (zeroed)
 *   stub verify     -> pred Vec (b*(s+1))
 *   accept_batch    -> results Vec + a commit Vec PER ROW   <- b allocs
 *   clamp collect   -> clamp (b)
 *   stats clone     -> accepted_rows.to_vec() (b), survives the round
 * All but the last freed at round end.  The mirror reproduces exactly
 * this inventory.
 *
 * Returns the concatenated token streams (caller frees). */
static int32_t *run_rounds_aos(int b, int s, int rounds, uint64_t seed,
                               int *out_total) {
    Row *rows = xmalloc((size_t)b * sizeof(Row));
    for (int i = 0; i < b; i++) {
        rows[i].cap = 16;
        rows[i].tokens = xmalloc((size_t)rows[i].cap * sizeof(int32_t));
        make_prompt(i, seed, rows[i].tokens);
        rows[i].len = PROMPT_LEN;
    }
    RoundStats *history = xmalloc((size_t)rounds * sizeof(RoundStats));
    int w = s + 1;
    for (int r = 0; r < rounds; r++) {
        /* fresh batch vectors every round, freed at round end */
        int32_t *delta = xmalloc((size_t)(b * 2) * sizeof(int32_t));
        int32_t *dlens = xmalloc((size_t)b * sizeof(int32_t));
        int32_t *feed = calloc((size_t)(b * w), sizeof(int32_t));
        int32_t *draft = xmalloc((size_t)(b * s + 1) * sizeof(int32_t));
        int32_t *pred = xmalloc((size_t)(b * w) * sizeof(int32_t));
        RowAcceptance *results = xmalloc((size_t)b * sizeof(RowAcceptance));
        uint32_t *clamp = xmalloc((size_t)b * sizeof(uint32_t));
        if (!feed) {
            exit(1);
        }
        for (int i = 0; i < b; i++) {
            int32_t t = rows[i].tokens[rows[i].len - 1];
            delta[i * 2] = t; /* build_delta: last committed tokens */
            dlens[i] = 1;
            feed[i * w] = t;
            for (int j = 0; j < s; j++) {
                t = ssm_next(t);
                draft[i * s + j] = t;
                feed[i * w + 1 + j] = t;
            }
        }
        for (int i = 0; i < b * w; i++) {
            pred[i] = llm_next(feed[i]);
        }
        for (int i = 0; i < b; i++) {
            int a = 0;
            while (a < s && draft[i * s + a] == pred[i * w + a]) {
                a++;
            }
            /* accept_row: owned commit buffer per row */
            results[i].accepted = a;
            results[i].commit_len = a + 1;
            results[i].commit = xmalloc((size_t)(a + 1) * sizeof(int32_t));
            memcpy(results[i].commit, draft + i * s,
                   (size_t)a * sizeof(int32_t));
            results[i].commit[a] = pred[i * w + a];
        }
        for (int i = 0; i < b; i++) {
            Row *row = &rows[i];
            int n = results[i].commit_len;
            while (row->len + n > row->cap) {
                row->cap *= 2;
                row->tokens = realloc(row->tokens,
                                      (size_t)row->cap * sizeof(int32_t));
            }
            memcpy(row->tokens + row->len, results[i].commit,
                   (size_t)n * sizeof(int32_t));
            row->len += n;
            clamp[i] = (uint32_t)(row->len - 1);
        }
        /* stats clone survives the round (accept_samples.to_vec()) */
        history[r].accepted = xmalloc((size_t)b * sizeof(uint32_t));
        for (int i = 0; i < b; i++) {
            history[r].accepted[i] = (uint32_t)results[i].accepted;
            free(results[i].commit);
        }
        free(delta);
        free(dlens);
        free(feed);
        free(draft);
        free(pred);
        free(results);
        free(clamp);
    }
    int total = 0;
    for (int i = 0; i < b; i++) {
        total += rows[i].len;
    }
    int32_t *out = xmalloc((size_t)total * sizeof(int32_t));
    int at = 0;
    for (int i = 0; i < b; i++) {
        memcpy(out + at, rows[i].tokens, (size_t)rows[i].len * sizeof(int32_t));
        at += rows[i].len;
        free(rows[i].tokens);
    }
    for (int r = 0; r < rounds; r++) {
        free(history[r].accepted);
    }
    free(history);
    free(rows);
    *out_total = total;
    return out;
}

/* ---- after: flat SoA arena + reused round scratch -------------------- */

static int32_t *run_rounds_soa(int b, int s, int rounds, uint64_t seed,
                               int *out_total) {
    /* SoA columns + scratch, allocated once (the arena high-water mark) */
    int32_t *tokens = xmalloc((size_t)(b * STRIDE) * sizeof(int32_t));
    int *lens = xmalloc((size_t)b * sizeof(int));
    int w = s + 1;
    int32_t *feed = xmalloc((size_t)(b * w) * sizeof(int32_t));
    int32_t *draft = xmalloc((size_t)(b * s + 1) * sizeof(int32_t));
    int32_t *pred = xmalloc((size_t)(b * w) * sizeof(int32_t));
    uint32_t *accepted = xmalloc((size_t)b * sizeof(uint32_t));
    uint32_t *acc_hist = xmalloc((size_t)(rounds * b) * sizeof(uint32_t));
    for (int i = 0; i < b; i++) {
        make_prompt(i, seed, tokens + i * STRIDE);
        lens[i] = PROMPT_LEN;
    }
    for (int r = 0; r < rounds; r++) {
        for (int i = 0; i < b; i++) {
            int32_t t = tokens[i * STRIDE + lens[i] - 1];
            feed[i * w] = t;
            for (int j = 0; j < s; j++) {
                t = ssm_next(t);
                draft[i * s + j] = t;
                feed[i * w + 1 + j] = t;
            }
        }
        for (int i = 0; i < b * w; i++) {
            pred[i] = llm_next(feed[i]);
        }
        for (int i = 0; i < b; i++) {
            int a = 0;
            while (a < s && draft[i * s + a] == pred[i * w + a]) {
                a++;
            }
            int32_t *dst = tokens + i * STRIDE + lens[i];
            memcpy(dst, draft + i * s, (size_t)a * sizeof(int32_t));
            dst[a] = pred[i * w + a];
            lens[i] += a + 1;
            accepted[i] = (uint32_t)a;
        }
        memcpy(acc_hist + r * b, accepted, (size_t)b * sizeof(uint32_t));
    }
    int total = 0;
    for (int i = 0; i < b; i++) {
        total += lens[i];
    }
    int32_t *out = xmalloc((size_t)total * sizeof(int32_t));
    int at = 0;
    for (int i = 0; i < b; i++) {
        memcpy(out + at, tokens + i * STRIDE, (size_t)lens[i] * sizeof(int32_t));
        at += lens[i];
    }
    free(tokens);
    free(lens);
    free(feed);
    free(draft);
    free(pred);
    free(accepted);
    free(acc_hist);
    *out_total = total;
    return out;
}

/* ---- driver ---------------------------------------------------------- */

typedef int32_t *(*variant_fn)(int, int, int, uint64_t, int *);

static double best_of(variant_fn fn, int b, int s, int rounds, uint64_t seed,
                      int reps) {
    double best = 0.0;
    for (int rep = 0; rep < reps; rep++) {
        int total;
        double t0 = now_s();
        int32_t *out = fn(b, s, rounds, seed, &total);
        double rps = (double)rounds / (now_s() - t0);
        free(out);
        if (rps > best) {
            best = rps;
        }
    }
    return best;
}

int main(int argc, char **argv) {
    int rounds = argc > 1 ? atoi(argv[1]) : 200;
    int reps = argc > 2 ? atoi(argv[2]) : 5;
    int grid_b[] = {1, 8, 16, 32};
    int grid_s[] = {0, 2, 4, 6};
    if (rounds * 7 + PROMPT_LEN >= STRIDE) {
        fprintf(stderr, "rounds too large for STRIDE\n");
        return 1;
    }
    for (int bi = 0; bi < 4; bi++) {
        for (int si = 0; si < 4; si++) {
            int b = grid_b[bi], s = grid_s[si];
            uint64_t seed = 0x517eULL + (uint64_t)b;
            /* fidelity guard: identical committed tokens */
            int n_aos, n_soa;
            int32_t *aos = run_rounds_aos(b, s, rounds, seed, &n_aos);
            int32_t *soa = run_rounds_soa(b, s, rounds, seed, &n_soa);
            if (n_aos != n_soa ||
                memcmp(aos, soa, (size_t)n_aos * sizeof(int32_t)) != 0) {
                fprintf(stderr, "variant divergence at b=%d s=%d\n", b, s);
                return 1;
            }
            free(aos);
            free(soa);
            double rps_aos = best_of(run_rounds_aos, b, s, rounds, seed, reps);
            double rps_soa = best_of(run_rounds_soa, b, s, rounds, seed, reps);
            printf("%d %d %.1f %.1f\n", b, s, rps_aos, rps_soa);
            fflush(stdout);
        }
    }
    return 0;
}
