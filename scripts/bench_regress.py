#!/usr/bin/env python3
"""CI bench-regress gate: diff a fresh `BENCH_micro_hotpath.json` against
the committed baseline and fail on a >15% rounds/s regression.

Stdlib only.  The headline metric is `rps_b32_s4` — the largest
(batch x spec-len) cell of the stub-backend decode grid, where the
SoA/arena hot path matters most.

Comparability rule: the two documents are hard-gated only when their
configs describe the same measurement — same `backend` (Rust benches
omit the key; the C mirror sets `stub-mirror-c`) and same `scale`.
A Rust-measured number must never fail CI against a mirror-measured
baseline (different machine, different harness): in that case, and for
sub-threshold deltas, the script prints an advisory line and exits 0.

Usage:
    bench_regress.py FRESH COMMITTED [--key rps_b32_s4] [--threshold 0.15]

Exit status: 1 on a comparable >threshold regression, else 0.
"""

import argparse
import json
import sys
from pathlib import Path


def load(path: Path) -> dict:
    try:
        return json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"bench-regress: cannot read {path}: {e}")


def provenance(doc: dict) -> tuple:
    cfg = doc.get("config", {}) or {}
    return (cfg.get("backend", "rust"), cfg.get("scale", "unknown"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", type=Path)
    ap.add_argument("committed", type=Path)
    ap.add_argument("--key", default="rps_b32_s4")
    ap.add_argument("--threshold", type=float, default=0.15)
    args = ap.parse_args()

    fresh = load(args.fresh)
    committed = load(args.committed)
    try:
        new = float(fresh["metrics"][args.key])
        old = float(committed["metrics"][args.key])
    except (KeyError, TypeError, ValueError) as e:
        sys.exit(f"bench-regress: missing metric {args.key!r}: {e}")
    if old <= 0.0:
        sys.exit(f"bench-regress: committed {args.key} is non-positive ({old})")

    delta = new / old - 1.0
    fresh_prov = provenance(fresh)
    committed_prov = provenance(committed)
    comparable = fresh_prov == committed_prov

    print(
        f"bench-regress: {args.key} fresh={new:.1f} committed={old:.1f} "
        f"delta={delta:+.1%} (threshold -{args.threshold:.0%})"
    )
    if not comparable:
        print(
            f"bench-regress: ADVISORY ONLY — provenance differs "
            f"(fresh {fresh_prov}, committed {committed_prov}); once a "
            f"Rust-measured baseline is committed this becomes gating"
        )
        return 0
    if delta < -args.threshold:
        print(
            f"bench-regress: FAIL — {args.key} regressed {-delta:.1%} "
            f"(> {args.threshold:.0%}) against the committed baseline"
        )
        return 1
    if delta < 0:
        print(f"bench-regress: advisory — {args.key} down {-delta:.1%}, within budget")
    else:
        print(f"bench-regress: OK — {args.key} improved or held")
    return 0


if __name__ == "__main__":
    sys.exit(main())
