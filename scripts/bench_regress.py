#!/usr/bin/env python3
"""CI bench-regress gate: diff a fresh `BENCH_micro_hotpath.json` against
the committed baseline and fail on a >15% rounds/s regression.

Stdlib only.  The headline metric is `rps_b32_s4` — the largest
(batch x spec-len) cell of the stub-backend decode grid, where the
SoA/arena hot path matters most.

Comparability rule: the two documents are hard-gated only when their
configs describe the same measurement — same `backend` (Rust benches
omit the key; the C mirror sets `stub-mirror-c`) and same `scale`.
A Rust-measured number must never fail CI against a mirror-measured
baseline (different machine, different harness): in that case, and for
sub-threshold deltas, the script prints an advisory line and exits 0.

Baseline promotion: `--promote-to PATH` stages the fresh document as a
commit-ready baseline whenever it is Rust-measured and the committed
baseline still carries mirror provenance.  CI uploads the staged file
as an artifact; committing it at the repo root replaces the C-mirror
numbers and flips this gate from advisory to gating on the next run.

Usage:
    bench_regress.py FRESH COMMITTED [--key rps_b32_s4] [--threshold 0.15]
                     [--promote-to PATH]

Exit status: 1 on a comparable >threshold regression, else 0.
"""

import argparse
import json
import sys
from pathlib import Path


def load(path: Path) -> dict:
    try:
        return json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"bench-regress: cannot read {path}: {e}")


def provenance(doc: dict) -> tuple:
    cfg = doc.get("config", {}) or {}
    return (cfg.get("backend", "rust"), cfg.get("scale", "unknown"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", type=Path)
    ap.add_argument("committed", type=Path)
    ap.add_argument("--key", default="rps_b32_s4")
    ap.add_argument("--threshold", type=float, default=0.15)
    ap.add_argument(
        "--promote-to",
        type=Path,
        default=None,
        help="stage the fresh doc as a commit-ready baseline when it is "
        "Rust-measured and the committed baseline is still the mirror",
    )
    args = ap.parse_args()

    fresh = load(args.fresh)
    committed = load(args.committed)
    try:
        new = float(fresh["metrics"][args.key])
        old = float(committed["metrics"][args.key])
    except (KeyError, TypeError, ValueError) as e:
        sys.exit(f"bench-regress: missing metric {args.key!r}: {e}")
    if old <= 0.0:
        sys.exit(f"bench-regress: committed {args.key} is non-positive ({old})")

    delta = new / old - 1.0
    fresh_prov = provenance(fresh)
    committed_prov = provenance(committed)
    comparable = fresh_prov == committed_prov

    print(
        f"bench-regress: {args.key} fresh={new:.1f} committed={old:.1f} "
        f"delta={delta:+.1%} (threshold -{args.threshold:.0%})"
    )
    if args.promote_to is not None:
        if fresh_prov[0] == "rust" and committed_prov[0] != "rust":
            args.promote_to.write_text(json.dumps(fresh, indent=1, sort_keys=True) + "\n")
            print(
                f"bench-regress: staged Rust-measured baseline at "
                f"{args.promote_to} — commit it as BENCH_micro_hotpath.json "
                f"at the repo root to make this gate gating"
            )
        elif fresh_prov[0] != "rust":
            print("bench-regress: not staging a baseline — fresh doc is not Rust-measured")
        else:
            print("bench-regress: baseline already Rust-measured; nothing to promote")

    if not comparable:
        print(
            f"bench-regress: ADVISORY ONLY — provenance differs "
            f"(fresh {fresh_prov}, committed {committed_prov}); once a "
            f"Rust-measured baseline is committed this becomes gating"
        )
        return 0
    if delta < -args.threshold:
        print(
            f"bench-regress: FAIL — {args.key} regressed {-delta:.1%} "
            f"(> {args.threshold:.0%}) against the committed baseline"
        )
        return 1
    if delta < 0:
        print(f"bench-regress: advisory — {args.key} down {-delta:.1%}, within budget")
    else:
        print(f"bench-regress: OK — {args.key} improved or held")
    return 0


if __name__ == "__main__":
    sys.exit(main())
