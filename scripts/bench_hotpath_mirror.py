#!/usr/bin/env python3
"""Native mirror of `cargo bench --bench micro_hotpath`'s rounds/s grid.

The build container for this repo has no Rust toolchain, but the perf
trajectory (ROADMAP item 5) needs a recorded before/after pair for the
zero-allocation hot-path PR.  This script compiles and runs
`bench_hotpath_mirror.c` — a C re-implementation of the stub-backend
decode round in both its pre-refactor shape (AoS rows + per-round Vec
churn, including the per-row commit allocation the old `accept_row`
did) and its post-refactor shape (flat SoA token arena + reused
round-scratch buffers, zero allocations per round).  C shares Rust's
memory economics (real malloc, unboxed ints, ~ns stub model), so the
measured delta isolates what the PR changed; a CPython mirror cannot
say the same (interpreter boxing swamps allocator behavior — tried and
rejected).

The C program asserts both variants commit byte-identical tokens before
anything is timed.

Output: `BENCH_micro_hotpath.json` (after) and
`BENCH_micro_hotpath.before.json` at the repo root, in the exact
`telemetry::bench::bench_report_custom` schema — same field set, same
FNV-1a config fingerprint over the Rust-compatible compact
serialization, same `.git/HEAD` SHA resolution.  Provenance is recorded
in `config` so `scripts/bench_regress.py` never hard-gates a
Rust-measured number against a mirror-measured one.

Usage: python3 scripts/bench_hotpath_mirror.py [--rounds N] [--reps R]
"""

import argparse
import subprocess
import tempfile
from pathlib import Path

MASK = (1 << 64) - 1
GRID_B = [1, 8, 16, 32]
GRID_S = [0, 2, 4, 6]
HEADLINE = "rps_b32_s4"


# --- Rust-compatible JSON writing + provenance -------------------------


def _num(n):
    f = float(n)
    if f == int(f) and abs(f) < 9e15:
        return str(int(f))
    return repr(f)


def compact(v) -> str:
    """Matches rust/src/util/json.rs `Json::compact` (sorted keys)."""
    if v is None:
        return "null"
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        return _num(v)
    if isinstance(v, str):
        return '"' + v.replace("\\", "\\\\").replace('"', '\\"') + '"'
    if isinstance(v, list):
        return "[" + ",".join(compact(x) for x in v) + "]"
    if isinstance(v, dict):
        return "{" + ",".join(
            compact(k) + ":" + compact(v[k]) for k in sorted(v)
        ) + "}"
    raise TypeError(type(v))


def pretty(v, depth=0) -> str:
    """Matches `Json::pretty` (1-space indent, sorted keys)."""
    pad = " " * (depth + 1)
    if isinstance(v, list) and v:
        inner = ",\n".join(pad + pretty(x, depth + 1) for x in v)
        return "[\n" + inner + "\n" + " " * depth + "]"
    if isinstance(v, dict) and v:
        inner = ",\n".join(
            pad + compact(k) + ": " + pretty(v[k], depth + 1) for k in sorted(v)
        )
        return "{\n" + inner + "\n" + " " * depth + "}"
    return compact(v)


def fingerprint(config) -> str:
    """FNV-1a 64 over the compact form — same as `config_fingerprint`."""
    h = 0xCBF2_9CE4_8422_2325
    for byte in compact(config).encode():
        h ^= byte
        h = (h * 0x1_0000_0001_B3) & MASK
    return f"{h:016x}"


def git_sha(repo_root: Path) -> str:
    head = repo_root / ".git" / "HEAD"
    try:
        text = head.read_text().strip()
    except OSError:
        return "unknown"
    if text.startswith("ref: "):
        try:
            return (repo_root / ".git" / text[5:]).read_text().strip()
        except OSError:
            return "unknown"
    return text


def bench_report_custom(name, metrics, config, repo_root):
    return {
        "name": name,
        "metrics": metrics,
        "config_fingerprint": fingerprint(config),
        "config": config,
        "git_sha": git_sha(repo_root),
    }


# --- driver ------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--reps", type=int, default=9, help="best-of reps per cell")
    ap.add_argument("--out", type=Path, default=None)
    args = ap.parse_args()
    repo_root = Path(__file__).resolve().parents[1]
    out_dir = args.out or repo_root
    src = repo_root / "scripts" / "bench_hotpath_mirror.c"

    with tempfile.TemporaryDirectory() as tmp:
        exe = Path(tmp) / "hotpath_mirror"
        subprocess.run(
            ["cc", "-O2", "-Wall", "-Wextra", "-o", str(exe), str(src)],
            check=True,
        )
        res = subprocess.run(
            [str(exe), str(args.rounds), str(args.reps)],
            check=True,
            capture_output=True,
            text=True,
        )

    before_metrics = {}
    after_metrics = {}
    for line in res.stdout.strip().splitlines():
        b, s, rps_aos, rps_soa = line.split()
        key = f"rps_b{b}_s{s}"
        before_metrics[key] = float(rps_aos)
        after_metrics[key] = float(rps_soa)
        print(
            f"b={int(b):>2} s={s}: before {float(rps_aos):>11.0f} r/s   "
            f"after {float(rps_soa):>11.0f} r/s   "
            f"({float(rps_soa) / float(rps_aos):.2f}x)"
        )
    want = {f"rps_b{b}_s{s}" for b in GRID_B for s in GRID_S}
    assert set(before_metrics) == want, "mirror grid incomplete"

    speedup = after_metrics[HEADLINE] / before_metrics[HEADLINE]
    after_metrics["speedup_vs_before_b32_s4"] = round(speedup, 3)
    print(f"\nheadline {HEADLINE}: {speedup:.2f}x (target >= 1.30x)")

    base_config = {
        "bench": "micro_hotpath",
        "backend": "stub-mirror-c",
        "scale": "quick",
        "rounds": args.rounds,
        "reps": args.reps,
        "vocab": 512,
        "grid_b": GRID_B,
        "grid_s": GRID_S,
        "provenance": (
            "c-mirror of the stub-backend rounds/s grid -- the build "
            "container has no Rust toolchain; CI's quick-scale bench job "
            "regenerates the Rust-measured BENCH_micro_hotpath.json"
        ),
    }
    docs = [
        (
            "BENCH_micro_hotpath.before.json",
            dict(base_config, variant="aos-churn (pre-refactor hot path)"),
            before_metrics,
        ),
        (
            "BENCH_micro_hotpath.json",
            dict(base_config, variant="soa-arena (post-refactor hot path)"),
            after_metrics,
        ),
    ]
    for fname, config, metrics in docs:
        doc = bench_report_custom("micro_hotpath", metrics, config, repo_root)
        path = out_dir / fname
        path.write_text(pretty(doc) + "\n")
        print(f"-> {path}")

    if speedup < 1.3:
        raise SystemExit(
            f"headline speedup {speedup:.2f}x below the 1.3x acceptance bar"
        )


if __name__ == "__main__":
    main()
